//! Execution backends: one trait, two substrates.
//!
//! [`ExecBackend`] is the contract an engine worker drives: execute an
//! [`ArtifactEntry`] against host tensors, pre-warm entries, report cache
//! stats.  Two implementations exist (DESIGN.md §10):
//!
//! * `ExecutableStore` (`runtime::store`, behind the `pjrt` cargo
//!   feature) — the PJRT/XLA path: compiles the
//!   AOT-lowered HLO artifacts and runs them on the XLA CPU client.
//!   Requires `make artifacts` and the `pjrt` cargo feature (which links
//!   the prebuilt `xla_extension`).
//! * [`NativeFlash`] — a pure-Rust backend implementing the same pipelines
//!   with the paper's matmul reordering ([`crate::estimator::flash`]):
//!   blocked f32 dot tiles (explicit `std::simd` lanes under the `simd`
//!   feature), f64 row accumulators, query blocks spread over scoped
//!   threads.  Needs no artifacts, no Python, no XLA — the entire serving
//!   path (fit → debias → registry → co-batching → eval/grad →
//!   backpressure) runs on a fresh checkout.
//!
//! The native backend also keeps a **resident-model prepare cache**
//! (DESIGN.md §11): the O(n·d) per-dataset precomputation the flash
//! kernels need (transposed train matrix + squared norms,
//! [`flash::PreparedTrain`]) is cached keyed by the *pointer identity* of
//! the registry's `Arc<HostTensor>` train tensors, held through `Weak`
//! references — so a registry delete or LRU eviction invalidates the
//! entry automatically by dropping the last strong `Arc`, and the cache
//! can never pin a deleted model's memory.  The cache ([`PrepareCache`])
//! is **shared across every native worker of one engine** (the prepared
//! form is an immutable `Arc` behind a mutex'd slot list), so
//! multi-worker native serving prepares each resident model once, not
//! once per worker.
//!
//! When a tuning table ([`crate::tuner::TuningTable`], written by
//! `flash-sdkde tune`, loaded via `serve --tuning`) is present, the
//! backend consults it at prepare time: a nearest-bucket lookup picks the
//! measured-best `block_q`/`block_t` for the model's `(d, n, m)` workload
//! (threads and the SIMD flag stay engine-owned), falling back to the
//! static default when the table has no cell for the dimension.  The
//! choice is cached in the model's prepare slot, so the hot path pays
//! zero lookup cost after first touch; `StoreStats.tuned_lookups` /
//! `tuned_fallbacks` surface the behaviour (DESIGN.md §13).
//!
//! Both backends execute against the *same* bucket/manifest shapes, so the
//! coordinator, batcher, wire protocol and every example behave
//! identically on either; when no artifacts exist the native path serves a
//! synthesized manifest ([`crate::runtime::Manifest::synthetic`]).

use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::artifact::{ArtifactEntry, Manifest};
use super::tensor::HostTensor;
use crate::approx::{deann::DeannIndex, rff::RffSketch, ApproxParams};
use crate::estimator::flash::{self, TileConfig};
use crate::tuner::TuningTable;
use crate::util::timer::PhaseTimer;

/// Result of one artifact execution (either backend).
#[derive(Debug)]
pub struct ExecOutput {
    /// Output tensors in the entry's declared order.
    pub outputs: Vec<HostTensor>,
    /// Phases: "h2d" / "execute" / "d2h" (+ "compile" on a PJRT cache
    /// miss); the native backend reports a single "execute" phase.
    pub timings: PhaseTimer,
}

/// Cache statistics for the info command / metrics endpoint.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StoreStats {
    /// Executables compiled (PJRT; 0 for native).
    pub compiles: u64,
    /// Executable-cache hits (PJRT; 0 for native).
    pub hits: u64,
    /// Artifact executions served.
    pub executions: u64,
    /// Total wall time spent compiling (PJRT).
    pub compile_time: Duration,
    /// Prepare-cache hits (native; 0 for PJRT).  A hit means a query
    /// chunk reused a resident model's [`flash::PreparedTrain`] instead
    /// of re-deriving the transposed train matrix + squared norms.
    /// Counted in the engine-shared [`PrepareCache`], so every worker's
    /// `stats()` reports the engine-wide number.
    pub prepare_hits: u64,
    /// Prepare-cache misses (native; 0 for PJRT) — first touch of a
    /// model's tensors, or re-prepare after the registry dropped them.
    /// Engine-wide, like `prepare_hits`.
    pub prepare_misses: u64,
    /// Tuning-table lookups that found a cell and applied its block
    /// shapes (native with `--tuning`; 0 when no table is loaded).
    /// Engine-wide, like `prepare_hits`.
    pub tuned_lookups: u64,
    /// Tuning-table lookups that fell back to the static default because
    /// the loaded table has no cell for the workload's dimension.  Stays
    /// 0 when no table is loaded — an absent table is not a fallback.
    /// Engine-wide, like `prepare_hits`.
    pub tuned_fallbacks: u64,
    /// Executions served by the approximate path (native; 0 for PJRT):
    /// approx-budget density chunks answered by the DEANN index / RFF
    /// sketch instead of the exact sweep (DESIGN.md §14).  Engine-wide,
    /// like `prepare_hits`.
    pub approx_queries: u64,
    /// Approx-budget executions the backend recognised but routed back
    /// to the exact path because the *pipeline* has no approximate
    /// estimator — gradient/Laplace/fit ([`ApproxOffer::Unsupported`]).
    /// The complementary cause — a backend with no approximate path at
    /// all ([`ApproxOffer::Declined`]) — is counted by the coordinator
    /// (`engine.declined` in the stats document), since such a backend
    /// has nowhere to count.  Engine-wide, like `prepare_hits`.
    pub unsupported_mode: u64,
    /// RFF probe-cache evictions: sketch slots pushed out of a model's
    /// bounded per-model LRU (`MAX_SKETCHES_PER_MODEL` = 8) by distinct
    /// `(h, rel_err)` budgets.  Nonzero means a tenant is sweeping
    /// budgets — the bound is what keeps that sweep from growing backend
    /// memory without limit.  Engine-wide, like `prepare_hits`.
    pub sketch_evictions: u64,
    /// Kernel matrix–vector executions served (the `matvec` pipeline,
    /// DESIGN.md §17; native; 0 for PJRT, which has no matvec
    /// artifacts).  Engine-wide, like `prepare_hits`.
    pub matvec_queries: u64,
}

/// Outcome of offering an execution to a backend's approximate path
/// ([`ExecBackend::execute_approx`]).  The two non-served outcomes both
/// mean "run the exact path", but for *different reasons* that operators
/// need to tell apart in stats: a user asking for an approx gradient
/// (`Unsupported` → `engine.unsupported_mode`) is not the same signal as
/// serving on a backend with no approximate machinery at all
/// (`Declined` → the coordinator-counted `engine.declined`).
#[derive(Debug)]
pub enum ApproxOffer {
    /// The backend served the request approximately, within budget.
    Served(ExecOutput),
    /// The backend has approximate estimators, but not for this entry's
    /// pipeline (grad/Laplace/fit on the native backend).
    Unsupported,
    /// The backend has no approximate path at all (PJRT, and any
    /// implementation keeping the trait default).
    Declined,
}

/// What an engine worker drives.  Implementations are single-thread
/// objects (PJRT handles are not `Send`); each worker constructs its own
/// via [`BackendKind::open`] on its own thread.
pub trait ExecBackend {
    /// Execute an artifact entry with validated host tensors.
    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[Arc<HostTensor>]) -> Result<ExecOutput>;

    /// Try to execute an entry through the backend's *approximate* path
    /// within the resolved error budget (DESIGN.md §14).  A non-served
    /// [`ApproxOffer`] means the caller must run
    /// [`execute`](Self::execute), with the variant recording *why*:
    /// `Unsupported` for a pipeline with no approximate estimator,
    /// `Declined` for a backend with none at all — which is exactly what
    /// the default implementation says.  `Err` is reserved for real
    /// failures (bad shapes, torn entries), never for "cannot
    /// approximate".
    fn execute_approx(
        &mut self,
        entry: &ArtifactEntry,
        inputs: &[Arc<HostTensor>],
        params: &ApproxParams,
    ) -> Result<ApproxOffer> {
        let _ = (entry, inputs, params);
        Ok(ApproxOffer::Declined)
    }

    /// Pre-warm an entry (compile for PJRT; no-op for native).
    fn warm(&mut self, entry: &ArtifactEntry) -> Result<Duration>;

    /// Counters for the stats endpoint.
    fn stats(&self) -> StoreStats;

    /// Number of compiled executables resident (0 for native).
    fn cached_len(&self) -> usize;

    /// Human-readable substrate name for logs.
    fn platform(&self) -> String;
}

/// Which execution backend serves requests (`backend = pjrt | native` in
/// the config file, `--backend` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// AOT-compiled XLA artifacts via PJRT (requires `make artifacts`).
    #[default]
    Pjrt,
    /// Pure-Rust tiled flash kernels (no artifacts required).
    Native,
}

impl BackendKind {
    /// Parse a config/CLI spelling (`"pjrt"`/`"xla"`, `"native"`/`"cpu"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" | "xla" => Some(Self::Pjrt),
            "native" | "native-flash" | "cpu" => Some(Self::Native),
            _ => None,
        }
    }

    /// Canonical config-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Pjrt => "pjrt",
            Self::Native => "native",
        }
    }

    /// Construct the backend on the calling thread.  `manifest` is kept by
    /// the PJRT store for artifact paths; the native backend needs only
    /// the entries the engine hands it per request.  `pool_peers` is how
    /// many sibling backends share this machine (engine workers): the
    /// native backend divides its kernel-thread budget by it so a
    /// multi-worker engine does not oversubscribe the cores.  `cache` is
    /// the engine's shared prepare cache — every native worker of one
    /// engine gets a clone of the same cache, sized by the coordinator
    /// from `registry_capacity` so every resident model fits.  `tuning`
    /// is the optional tile-tuning table (`serve --tuning`).  PJRT
    /// ignores both; its executable cache is keyed by artifact.
    pub fn open(
        self,
        manifest: Manifest,
        pool_peers: usize,
        cache: PrepareCache,
        tuning: Option<Arc<TuningTable>>,
    ) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendKind::Pjrt => {
                let _ = (cache, tuning);
                #[cfg(feature = "pjrt")]
                {
                    Ok(Box::new(super::store::ExecutableStore::open(manifest)?))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    let _ = manifest;
                    bail!(
                        "backend \"pjrt\" is unavailable: this binary was built \
                         without the `pjrt` feature — use backend = \"native\" \
                         or rebuild with `--features pjrt`"
                    )
                }
            }
            BackendKind::Native => {
                drop(manifest);
                let threads =
                    (flash::default_threads() / pool_peers.max(1)).max(1);
                Ok(Box::new(NativeFlash::with_cache(
                    TileConfig { threads, ..TileConfig::default() },
                    cache,
                    tuning,
                )))
            }
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Check inputs against an entry's manifest signature (the wire-order
/// contract with model.py) — shared by both backends.
pub fn validate_inputs<T: std::borrow::Borrow<HostTensor>>(
    entry: &ArtifactEntry,
    inputs: &[T],
) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "artifact {} expects {} inputs, got {}",
            entry.key(),
            entry.inputs.len(),
            inputs.len()
        );
    }
    for (i, (spec, t)) in entry.inputs.iter().zip(inputs).enumerate() {
        let t = t.borrow();
        if spec.shape != t.shape() {
            bail!(
                "input {} ({}) of {}: expected shape {:?}, got {:?}",
                i,
                spec.name,
                entry.key(),
                spec.shape,
                t.shape()
            );
        }
    }
    Ok(())
}

/// One prepare-cache entry: `Weak` handles to the registry's train
/// tensors, the shared prepared form, and the tile configuration chosen
/// for this model (the tuning-table lookup runs once, at slot creation —
/// hits reuse the cached choice, so the hot path pays zero lookup cost).
/// Holding only `Weak`s is the invalidation mechanism — when the
/// registry (and every handle) drops a model, the upgrade fails and the
/// slot is purged on the next touch, so the cache can neither serve a
/// stale model nor keep its memory alive.
struct PrepareSlot {
    x: Weak<HostTensor>,
    w: Weak<HostTensor>,
    prep: Arc<flash::PreparedTrain>,
    tile: TileConfig,
    /// DEANN cell index (DESIGN.md §14), built lazily on the model's
    /// first approx-budget query — exact-only serving never pays for it.
    /// Like `prep`, it depends only on the train tensors, so one index
    /// serves every bandwidth and budget.
    deann: Option<Arc<DeannIndex>>,
    /// RFF sketches keyed by `(h_bits, rel_err_bits)`, **including
    /// negative entries** (`sketch: None` = "probed, not viable"), so
    /// the viability probe runs once per model/budget, not per query.
    sketches: Vec<SketchSlot>,
}

/// One cached RFF probe result for a `(bandwidth, budget)` pair.
struct SketchSlot {
    h_bits: u64,
    rel_err_bits: u64,
    sketch: Option<Arc<RffSketch>>,
}

/// Bound on cached RFF probe results per model slot — eviction is
/// least-recently-used (probe hits refresh their entry) and counted in
/// [`StoreStats::sketch_evictions`]; serving traffic uses a handful of
/// budgets at most, so churn here would indicate a client sweeping
/// budgets, not a hot path to protect — the bound is what keeps such a
/// sweep from growing backend memory without limit.
const MAX_SKETCHES_PER_MODEL: usize = 8;

/// Default upper bound on resident prepared models per cache — the
/// standalone-constructor fallback, matching the default registry
/// capacity.  The serving path does better: `Coordinator::start` sizes
/// the cache from `Config::registry_capacity` (via
/// [`Engine::start`](super::Engine::start) →
/// [`BackendKind::open`]), so every resident model can keep its prepared
/// form and round-robin load over a large registry cannot thrash the
/// cache.  Eviction is least-recently-used: hits refresh their slot,
/// dead slots are purged before counting.
pub const DEFAULT_PREPARE_CAP: usize = 64;

/// The resident-model prepare cache, shared by every native worker of
/// one engine: a bounded, mutex'd slot list (`Mutex<Vec<PrepareSlot>>`)
/// whose prepared forms are immutable `Arc`s — cloning the cache clones
/// the handle, not the slots.  `Engine::start` creates one per engine
/// and hands each worker a clone through [`BackendKind::open`], so
/// multi-worker native serving prepares a resident model **once**
/// instead of once per worker (the PR 3 follow-up ROADMAP named).
/// Standalone [`NativeFlash`] constructors make a private one.
#[derive(Clone)]
pub struct PrepareCache {
    inner: Arc<Mutex<CacheInner>>,
}

struct CacheInner {
    slots: Vec<PrepareSlot>,
    cap: usize,
    /// Cache-wide counters (surfaced through every worker's `stats()`):
    /// with the cache shared across engine workers, per-worker counters
    /// would make `stats()`'s sample-one-worker read misleading — the
    /// worker that answers may not be the one that prepared.
    prepare_hits: u64,
    prepare_misses: u64,
    tuned_lookups: u64,
    tuned_fallbacks: u64,
    approx_queries: u64,
    unsupported_mode: u64,
    sketch_evictions: u64,
    matvec_queries: u64,
}

impl CacheInner {
    fn purge_dead(&mut self) {
        self.slots
            .retain(|s| s.x.upgrade().is_some() && s.w.upgrade().is_some());
    }
}

impl PrepareCache {
    /// Cache bounded at `cap` slots (a zero cap is clamped to 1: the
    /// eviction pops the front slot and must never pop an empty vec).
    pub fn new(cap: usize) -> Self {
        PrepareCache {
            inner: Arc::new(Mutex::new(CacheInner {
                slots: Vec::new(),
                cap: cap.max(1),
                prepare_hits: 0,
                prepare_misses: 0,
                tuned_lookups: 0,
                tuned_fallbacks: 0,
                approx_queries: 0,
                unsupported_mode: 0,
                sketch_evictions: 0,
                matvec_queries: 0,
            })),
        }
    }

    /// The slot bound this cache was built with.
    pub fn capacity(&self) -> usize {
        self.lock().cap
    }

    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        self.inner.lock().expect("prepare cache poisoned")
    }
}

/// The native flash backend: dispatches the manifest pipelines onto the
/// tiled kernels in [`crate::estimator::flash`].
///
/// Numerics policy (DESIGN.md §10/§11): f32 dot tiles, f64 norms and row
/// accumulators, identical formulas and masked-row semantics to the
/// scalar oracle; the conformance suite pins the agreement at rtol ≤ 2e-3
/// (the f32 cross-term rounding, same order as the XLA f32 kernels).
/// Serving-path executions (`kde`, `laplace`, `score_eval`) reuse a
/// cached [`flash::PreparedTrain`] per resident model (see module docs);
/// the fit pipelines prepare inline since their train set is one-shot.
pub struct NativeFlash {
    tile: TileConfig,
    stats: StoreStats,
    cache: PrepareCache,
    tuning: Option<Arc<TuningTable>>,
}

impl NativeFlash {
    /// Backend with the default tile configuration.
    pub fn new() -> Self {
        Self::with_tile(TileConfig::default())
    }

    /// Pin tile sizes / thread count (conformance + ablation harnesses).
    pub fn with_tile(tile: TileConfig) -> Self {
        Self::with_tile_and_capacity(tile, DEFAULT_PREPARE_CAP)
    }

    /// Pin tile configuration *and* the prepare-cache bound, with a
    /// private (unshared) cache and no tuning table.
    pub fn with_tile_and_capacity(tile: TileConfig, prepare_cap: usize) -> Self {
        Self::with_cache(tile, PrepareCache::new(prepare_cap), None)
    }

    /// The full serving constructor: pin the tile configuration, attach
    /// an engine-shared [`PrepareCache`], and optionally a tile-tuning
    /// table whose nearest-bucket winners override `block_q`/`block_t`
    /// per workload (threads and the SIMD flag stay from `tile` — the
    /// engine owns the per-worker thread budget, the build owns SIMD).
    pub fn with_cache(
        tile: TileConfig,
        cache: PrepareCache,
        tuning: Option<Arc<TuningTable>>,
    ) -> Self {
        NativeFlash { tile, stats: StoreStats::default(), cache, tuning }
    }

    /// The static tile configuration this backend falls back to.
    pub fn tile(&self) -> &TileConfig {
        &self.tile
    }

    /// The prepare-cache bound this backend was built with.
    pub fn prepare_capacity(&self) -> usize {
        self.cache.capacity()
    }

    /// Live prepare-cache entries (dead slots purged first).
    pub fn prepared_len(&mut self) -> usize {
        let mut inner = self.cache.lock();
        inner.purge_dead();
        inner.slots.len()
    }

    /// Drop prepare-cache slots whose model tensors have been released
    /// (registry delete / LRU eviction).  Runs automatically on every
    /// cache access; exposed for tests and explicit maintenance.
    pub fn prepared_gc(&mut self) {
        self.cache.lock().purge_dead();
    }

    /// The tile configuration serving a `(d, n, m)` workload: the tuning
    /// table's nearest-bucket winner with this backend's threads/SIMD
    /// flag, or the static default.  Counts `tuned_lookups` /
    /// `tuned_fallbacks`; with no table loaded neither counter moves.
    fn choose_tile(&mut self, d: usize, n: usize, m: usize) -> TileConfig {
        let Some(table) = &self.tuning else {
            return self.tile;
        };
        match table.lookup(d, n, m) {
            Some(cell) => {
                self.cache.lock().tuned_lookups += 1;
                cell.apply(self.tile)
            }
            None => {
                self.cache.lock().tuned_fallbacks += 1;
                self.tile
            }
        }
    }

    /// Resolve the prepared form (and cached tile choice) of a (train,
    /// weights) tensor pair, reusing the cached one when the *same
    /// allocations* were prepared before.  Identity is pointer equality
    /// of the `Arc` allocations: dead slots are purged first, so a
    /// surviving slot's address belongs to a live allocation and cannot
    /// alias a freed model (the caller's strong `Arc` pins its own
    /// address for the duration — no ABA).  `m` is this request's query
    /// rows — it feeds the tuning lookup on slot creation only; later
    /// hits reuse the slot's choice (query buckets are stable per model
    /// on the serving path, and re-running the lookup per request would
    /// put table scans back on the hot path).
    fn prepared_for(
        &mut self,
        x: &Arc<HostTensor>,
        w: &Arc<HostTensor>,
        d: usize,
        m: usize,
    ) -> Result<(Arc<flash::PreparedTrain>, TileConfig)> {
        let find = |slots: &[PrepareSlot]| {
            slots.iter().position(|s| {
                std::ptr::eq(s.x.as_ptr(), Arc::as_ptr(x))
                    && std::ptr::eq(s.w.as_ptr(), Arc::as_ptr(w))
                    && s.prep.d() == d
            })
        };
        {
            let mut inner = self.cache.lock();
            inner.purge_dead();
            if let Some(pos) = find(&inner.slots) {
                inner.prepare_hits += 1;
                // Refresh: move the slot to the back so eviction is LRU,
                // not FIFO — churn cannot evict the hottest model first.
                let slot = inner.slots.remove(pos);
                let out = (Arc::clone(&slot.prep), slot.tile);
                inner.slots.push(slot);
                return Ok(out);
            }
            inner.prepare_misses += 1;
        }
        // Miss: prepare outside the lock so sibling workers serving
        // other (cached) models are not stalled behind this O(n·d) pass.
        let tile = self.choose_tile(d, w.len(), m);
        // Shape consistency was bailed on in execute() before any kernel
        // or prepare runs; the assert in PreparedTrain::new is vestigial.
        let prep = Arc::new(flash::PreparedTrain::new(x.data(), w.data(), d));
        let mut inner = self.cache.lock();
        if let Some(pos) = find(&inner.slots) {
            // A sibling worker prepared the same model while we did: use
            // the shared slot (one canonical prepared form + tile choice).
            let slot = &inner.slots[pos];
            return Ok((Arc::clone(&slot.prep), slot.tile));
        }
        if inner.slots.len() >= inner.cap {
            inner.slots.remove(0);
        }
        inner.slots.push(PrepareSlot {
            x: Arc::downgrade(x),
            w: Arc::downgrade(w),
            prep: Arc::clone(&prep),
            tile,
            deann: None,
            sketches: Vec::new(),
        });
        Ok((prep, tile))
    }

    /// Resolve the approximate estimators for a model at one bandwidth
    /// and budget: the per-model [`DeannIndex`] (always available) and
    /// the [`RffSketch`] for this `(h, rel_err)` pair when viable.  Both
    /// live in the model's prepare slot; like `prepared_for`, builds run
    /// *outside* the cache lock with a sibling re-check afterwards, so
    /// one worker's O(n·√n·d) index build never stalls siblings serving
    /// cached models.
    fn approx_for(
        &mut self,
        x: &Arc<HostTensor>,
        w: &Arc<HostTensor>,
        d: usize,
        m: usize,
        h: f64,
        rel_err: f64,
    ) -> Result<(Arc<DeannIndex>, Option<Arc<RffSketch>>)> {
        // Ensure the model has a slot — and the exact prepared form any
        // per-row fallback or later exact query wants anyway.
        self.prepared_for(x, w, d, m)?;
        let find = |slots: &[PrepareSlot]| {
            slots.iter().position(|s| {
                std::ptr::eq(s.x.as_ptr(), Arc::as_ptr(x))
                    && std::ptr::eq(s.w.as_ptr(), Arc::as_ptr(w))
                    && s.prep.d() == d
            })
        };

        // DEANN index: built once per model, bandwidth-independent.
        let cached = {
            let inner = self.cache.lock();
            find(&inner.slots).and_then(|p| inner.slots[p].deann.clone())
        };
        let deann = match cached {
            Some(idx) => idx,
            None => {
                let built = Arc::new(DeannIndex::build(x.data(), w.data(), d));
                let mut inner = self.cache.lock();
                match find(&inner.slots) {
                    // A sibling may have built it while we did: keep one
                    // canonical index per slot.
                    Some(p) => Arc::clone(
                        inner.slots[p].deann.get_or_insert(built),
                    ),
                    // Slot evicted meanwhile: serve the build uncached.
                    None => built,
                }
            }
        };

        // RFF sketch: one probe per (h, rel_err), negative results cached
        // too so non-viable regimes don't re-probe per query.  A probe
        // hit moves its entry to the back of the slot list, so the
        // bounded cache evicts least-recently-used: a tenant sweeping
        // budgets churns the cold tail, never the budget a steady
        // client keeps re-using.
        let key = (h.to_bits(), rel_err.to_bits());
        let touch = |slot: &mut PrepareSlot| {
            let p = slot
                .sketches
                .iter()
                .position(|s| (s.h_bits, s.rel_err_bits) == key)?;
            let entry = slot.sketches.remove(p);
            let sketch = entry.sketch.clone();
            slot.sketches.push(entry);
            Some(sketch)
        };
        let cached = {
            let mut inner = self.cache.lock();
            match find(&inner.slots) {
                Some(p) => touch(&mut inner.slots[p]),
                None => None,
            }
        };
        let sketch = match cached {
            Some(entry) => entry,
            None => {
                let built =
                    RffSketch::build(x.data(), w.data(), d, h, rel_err)
                        .map(Arc::new);
                let mut inner = self.cache.lock();
                match find(&inner.slots) {
                    Some(p) => {
                        if let Some(entry) = touch(&mut inner.slots[p]) {
                            entry // sibling probed first: share its result
                        } else {
                            if inner.slots[p].sketches.len()
                                >= MAX_SKETCHES_PER_MODEL
                            {
                                // Front = coldest (hits move to the back).
                                inner.slots[p].sketches.remove(0);
                                inner.sketch_evictions += 1;
                            }
                            inner.slots[p].sketches.push(SketchSlot {
                                h_bits: key.0,
                                rel_err_bits: key.1,
                                sketch: built.clone(),
                            });
                            built
                        }
                    }
                    None => built,
                }
            }
        };
        Ok((deann, sketch))
    }

    /// Positional input access with a typed error — validate_inputs only
    /// matches the arity against the *entry*, and a foreign manifest may
    /// declare fewer inputs than a pipeline needs; that must never panic
    /// a worker.
    fn input_arc<'a>(
        inputs: &'a [Arc<HostTensor>],
        idx: usize,
        name: &str,
    ) -> Result<&'a Arc<HostTensor>> {
        match inputs.get(idx) {
            Some(t) => Ok(t),
            None => bail!(
                "native pipeline needs input {idx} ({name}); entry declares {}",
                inputs.len()
            ),
        }
    }

    fn input<'a>(
        inputs: &'a [Arc<HostTensor>],
        idx: usize,
        name: &str,
    ) -> Result<&'a HostTensor> {
        Self::input_arc(inputs, idx, name).map(|t| t.as_ref())
    }

    fn scalar(inputs: &[Arc<HostTensor>], idx: usize, name: &str) -> Result<f64> {
        let t = Self::input(inputs, idx, name)?;
        if t.len() != 1 {
            bail!("input {idx} ({name}) must be a scalar, got shape {:?}", t.shape());
        }
        Ok(t.data()[0] as f64)
    }

    /// A `[rows, d]` input as a flat slice, with the row-width check the
    /// flash kernels would otherwise only `assert!` — a foreign manifest's
    /// inconsistent entry must be a typed error, never a worker panic.
    fn rows_input<'a>(
        inputs: &'a [Arc<HostTensor>],
        idx: usize,
        name: &str,
        d: usize,
    ) -> Result<&'a [f32]> {
        let t = Self::input(inputs, idx, name)?;
        if t.len() % d != 0 {
            bail!(
                "input {idx} ({name}) has {} values, not a multiple of d={d}",
                t.len()
            );
        }
        Ok(t.data())
    }
}

impl Default for NativeFlash {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecBackend for NativeFlash {
    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[Arc<HostTensor>]) -> Result<ExecOutput> {
        validate_inputs(entry, inputs)?;
        let d = entry.d;
        let mut timer = PhaseTimer::new();
        let start = Instant::now();

        // Every pipeline shares the (x, w) prefix; kernels treat w == 0 as
        // a masked row exactly like the oracle and the padded buckets.
        let x_arc = Self::input_arc(inputs, 0, "x")?;
        let w_arc = Self::input_arc(inputs, 1, "w")?;
        let x = x_arc.data();
        let w = w_arc.data();
        if !w.iter().any(|&v| v != 0.0) {
            bail!("artifact {}: no effective samples (all weights zero)", entry.key());
        }
        // A foreign manifest entry can be internally inconsistent in ways
        // validate_inputs cannot see (it only matches tensors against the
        // entry's own specs): reject them here as typed errors before the
        // kernels' asserts could panic the worker.
        if d == 0 {
            bail!("artifact {}: dimension must be >= 1", entry.key());
        }
        if x.len() != w.len() * d {
            bail!(
                "artifact {}: train tensors disagree: x has {} values, \
                 w has {} rows, d={d}",
                entry.key(),
                x.len(),
                w.len()
            );
        }

        let output = match entry.pipeline.as_str() {
            // Serving pipelines: the train side is a resident model's
            // tensors — reuse (or build) its cached prepared form and the
            // tile choice cached beside it.
            "kde" => {
                let y = Self::rows_input(inputs, 2, "y", d)?;
                let h = Self::scalar(inputs, 3, "h")?;
                let prep_start = Instant::now();
                let (train, tile) =
                    self.prepared_for(x_arc, w_arc, d, y.len() / d)?;
                timer.add("prepare", prep_start.elapsed());
                let dens = flash::kde_prepared(&train, y, h, &tile);
                HostTensor::vec1(dens.iter().map(|&v| v as f32).collect())
            }
            "laplace" => {
                let y = Self::rows_input(inputs, 2, "y", d)?;
                let h = Self::scalar(inputs, 3, "h")?;
                let prep_start = Instant::now();
                let (train, tile) =
                    self.prepared_for(x_arc, w_arc, d, y.len() / d)?;
                timer.add("prepare", prep_start.elapsed());
                let dens = flash::laplace_prepared(&train, y, h, &tile);
                HostTensor::vec1(dens.iter().map(|&v| v as f32).collect())
            }
            "score_eval" => {
                let y = Self::rows_input(inputs, 2, "y", d)?;
                let h = Self::scalar(inputs, 3, "h")?;
                let prep_start = Instant::now();
                let (train, tile) =
                    self.prepared_for(x_arc, w_arc, d, y.len() / d)?;
                timer.add("prepare", prep_start.elapsed());
                let s = flash::score_at_prepared(&train, y, h, &tile);
                HostTensor::matrix(
                    y.len() / d,
                    d,
                    s.iter().map(|&v| v as f32).collect(),
                )?
            }
            // Kernel matrix–vector product K·v (DESIGN.md §17): the eval
            // signature plus a train-side vector v [n] between y and h.
            // Rides the same prepared form and tile choice as densities.
            "matvec" => {
                let y = Self::rows_input(inputs, 2, "y", d)?;
                let v = Self::input(inputs, 3, "v")?;
                let h = Self::scalar(inputs, 4, "h")?;
                if v.len() != w.len() {
                    bail!(
                        "artifact {}: v has {} entries, train bucket has {} \
                         rows",
                        entry.key(),
                        v.len(),
                        w.len()
                    );
                }
                let prep_start = Instant::now();
                let (train, tile) =
                    self.prepared_for(x_arc, w_arc, d, y.len() / d)?;
                timer.add("prepare", prep_start.elapsed());
                let out =
                    flash::matvec_prepared(&train, v.data(), y, h, &tile);
                self.cache.lock().matvec_queries += 1;
                HostTensor::vec1(out.iter().map(|&v| v as f32).collect())
            }
            // Fit pipelines: the train set is one-shot (the registry
            // stores the *debiased* output, a different tensor), so
            // prepare inline and keep the cache for resident models; the
            // tuning lookup still applies (the score pass runs y = x, so
            // the query bucket is the train bucket).
            "sdkde_fit" => {
                let h = Self::scalar(inputs, 2, "h")?;
                let h_s = Self::scalar(inputs, 3, "h_score")?;
                let tile = self.choose_tile(d, w.len(), w.len());
                let x_sd = flash::debias(x, w, d, h, h_s, &tile);
                HostTensor::matrix(w.len(), d, x_sd)?
            }
            // Not routed by the coordinator (SD-KDE evals run "kde" over
            // the debiased set) but kept for parity with real manifests
            // and direct backend driving (benches, conformance).
            "sdkde_e2e" => {
                let y = Self::rows_input(inputs, 2, "y", d)?;
                let h = Self::scalar(inputs, 3, "h")?;
                let h_s = Self::scalar(inputs, 4, "h_score")?;
                let tile = self.choose_tile(d, w.len(), y.len() / d);
                let dens = flash::sdkde(x, w, y, d, h, h_s, &tile);
                HostTensor::vec1(dens.iter().map(|&v| v as f32).collect())
            }
            other => bail!(
                "native backend does not implement pipeline {other:?} \
                 (artifact {})",
                entry.key()
            ),
        };

        timer.add("execute", start.elapsed());
        if let Some(spec) = entry.outputs.first() {
            if !spec.shape.is_empty() && spec.shape != output.shape() {
                bail!(
                    "native {} produced shape {:?}, manifest says {:?}",
                    entry.key(),
                    output.shape(),
                    spec.shape
                );
            }
        }
        self.stats.executions += 1;
        Ok(ExecOutput { outputs: vec![output], timings: timer })
    }

    fn execute_approx(
        &mut self,
        entry: &ArtifactEntry,
        inputs: &[Arc<HostTensor>],
        params: &ApproxParams,
    ) -> Result<ApproxOffer> {
        // Only the density pipeline has approximate estimators
        // (DESIGN.md §14); gradients, Laplace and the fit pipelines are
        // unsupported modes, counted so operators can tell "user asked
        // for an approx gradient" apart from "backend has no approx
        // path" (the coordinator-counted `Declined`).
        if entry.pipeline.as_str() != "kde" {
            self.cache.lock().unsupported_mode += 1;
            return Ok(ApproxOffer::Unsupported);
        }
        validate_inputs(entry, inputs)?;
        let d = entry.d;
        let mut timer = PhaseTimer::new();
        let start = Instant::now();

        // Same boundary validation as the exact path: torn entries are
        // typed errors here too, never index-build panics.
        let x_arc = Self::input_arc(inputs, 0, "x")?;
        let w_arc = Self::input_arc(inputs, 1, "w")?;
        let x = x_arc.data();
        let w = w_arc.data();
        if !w.iter().any(|&v| v != 0.0) {
            bail!("artifact {}: no effective samples (all weights zero)", entry.key());
        }
        if d == 0 {
            bail!("artifact {}: dimension must be >= 1", entry.key());
        }
        if x.len() != w.len() * d {
            bail!(
                "artifact {}: train tensors disagree: x has {} values, \
                 w has {} rows, d={d}",
                entry.key(),
                x.len(),
                w.len()
            );
        }
        let y = Self::rows_input(inputs, 2, "y", d)?;
        let h = Self::scalar(inputs, 3, "h")?;
        let m = y.len() / d;

        let prep_start = Instant::now();
        let (deann, sketch) =
            self.approx_for(x_arc, w_arc, d, m, h, params.rel_err)?;
        timer.add("prepare", prep_start.elapsed());
        // Per row: the sketch when it accepts (n-independent), DEANN
        // otherwise.  Acceptance is deterministic, so the split — and
        // therefore the result — is bitwise-stable per (query, seed).
        let mut dens = Vec::with_capacity(m);
        for (i, q) in y.chunks_exact(d).enumerate() {
            let row = (params.row_offset + i) as u64;
            let v = sketch
                .as_deref()
                .and_then(|sk| sk.density(q, h, params.rel_err))
                .unwrap_or_else(|| {
                    deann.density(q, h, params.rel_err, params.seed, row)
                });
            dens.push(v as f32);
        }
        let output = HostTensor::vec1(dens);

        timer.add("execute", start.elapsed());
        if let Some(spec) = entry.outputs.first() {
            if !spec.shape.is_empty() && spec.shape != output.shape() {
                bail!(
                    "native approx {} produced shape {:?}, manifest says {:?}",
                    entry.key(),
                    output.shape(),
                    spec.shape
                );
            }
        }
        self.cache.lock().approx_queries += 1;
        self.stats.executions += 1;
        Ok(ApproxOffer::Served(ExecOutput {
            outputs: vec![output],
            timings: timer,
        }))
    }

    fn warm(&mut self, _entry: &ArtifactEntry) -> Result<Duration> {
        // Nothing to compile: the kernels are this binary.
        Ok(Duration::default())
    }

    fn stats(&self) -> StoreStats {
        // Executions are per worker; the prepare/tuning counters live in
        // the engine-shared cache, so whichever worker answers a stats
        // request reports the engine-wide numbers.
        let inner = self.cache.lock();
        StoreStats {
            prepare_hits: inner.prepare_hits,
            prepare_misses: inner.prepare_misses,
            tuned_lookups: inner.tuned_lookups,
            tuned_fallbacks: inner.tuned_fallbacks,
            approx_queries: inner.approx_queries,
            unsupported_mode: inner.unsupported_mode,
            sketch_evictions: inner.sketch_evictions,
            matvec_queries: inner.matvec_queries,
            ..self.stats
        }
    }

    fn cached_len(&self) -> usize {
        0
    }

    fn platform(&self) -> String {
        let lanes = if cfg!(feature = "simd") && self.tile.simd {
            "simd"
        } else {
            "auto-vec"
        };
        format!(
            "native-cpu (tiles {}x{}, {} threads, {lanes})",
            self.tile.block_q, self.tile.block_t, self.tile.threads
        )
    }
}

/// Resolve the manifest a backend serves: PJRT always loads the artifact
/// directory; the native backend loads it when present (identical buckets
/// to the compiled path) and synthesizes one otherwise.  A *corrupt*
/// manifest is a typed error for both — silent fallback would mask a torn
/// `make artifacts`.
pub fn resolve_manifest(kind: BackendKind, dir: &std::path::Path) -> Result<Manifest> {
    match kind {
        BackendKind::Pjrt => Manifest::load(dir),
        BackendKind::Native => {
            if dir.join("manifest.json").exists() {
                Manifest::load(dir)
            } else {
                Ok(Manifest::synthetic())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::native;
    use crate::runtime::artifact::TensorSpec;
    use crate::util::rng::Pcg64;

    fn kde_entry(n: usize, m: usize, d: usize) -> ArtifactEntry {
        ArtifactEntry {
            pipeline: "kde".into(),
            variant: "flash".into(),
            d,
            n,
            m,
            tiles: None,
            file: format!("native://kde/flash/d{d}/n{n}/m{m}"),
            inputs: vec![
                TensorSpec { name: "x".into(), shape: vec![n, d] },
                TensorSpec { name: "w".into(), shape: vec![n] },
                TensorSpec { name: "y".into(), shape: vec![m, d] },
                TensorSpec { name: "h".into(), shape: vec![] },
            ],
            outputs: vec![TensorSpec { name: "".into(), shape: vec![m] }],
        }
    }

    fn matvec_entry(n: usize, m: usize, d: usize) -> ArtifactEntry {
        ArtifactEntry {
            pipeline: "matvec".into(),
            variant: "flash".into(),
            d,
            n,
            m,
            tiles: None,
            file: format!("native://matvec/flash/d{d}/n{n}/m{m}"),
            inputs: vec![
                TensorSpec { name: "x".into(), shape: vec![n, d] },
                TensorSpec { name: "w".into(), shape: vec![n] },
                TensorSpec { name: "y".into(), shape: vec![m, d] },
                TensorSpec { name: "v".into(), shape: vec![n] },
                TensorSpec { name: "h".into(), shape: vec![] },
            ],
            outputs: vec![TensorSpec { name: "".into(), shape: vec![m] }],
        }
    }

    fn arcs(ts: Vec<HostTensor>) -> Vec<Arc<HostTensor>> {
        ts.into_iter().map(Arc::new).collect()
    }

    fn served(offer: ApproxOffer) -> ExecOutput {
        match offer {
            ApproxOffer::Served(out) => out,
            other => panic!("expected ApproxOffer::Served, got {other:?}"),
        }
    }

    #[test]
    fn backend_kind_parse_round_trip() {
        for k in [BackendKind::Pjrt, BackendKind::Native] {
            assert_eq!(BackendKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(BackendKind::parse("native-flash"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("XLA"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Pjrt);
    }

    #[test]
    fn native_executes_kde_entry_against_oracle() {
        let (n, m, d) = (40, 6, 2);
        let mut rng = Pcg64::seeded(3);
        let x = rng.normal_vec_f32(n * d);
        let y = rng.normal_vec_f32(m * d);
        let w = vec![1.0f32; n];
        let h = 0.55f64;

        let mut backend = NativeFlash::new();
        let entry = kde_entry(n, m, d);
        let out = backend
            .execute(
                &entry,
                &arcs(vec![
                    HostTensor::matrix(n, d, x.clone()).unwrap(),
                    HostTensor::vec1(w.clone()),
                    HostTensor::matrix(m, d, y.clone()).unwrap(),
                    HostTensor::scalar(h as f32),
                ]),
            )
            .expect("execute");
        assert_eq!(out.outputs.len(), 1);
        assert_eq!(out.outputs[0].shape(), &[m]);
        let want = native::kde(&x, &w, &y, d, h);
        for (a, b) in out.outputs[0].data().iter().zip(&want) {
            assert!(((*a as f64 - b) / b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(backend.stats().executions, 1);
        assert_eq!(backend.cached_len(), 0);
        assert!(backend.platform().contains("native-cpu"));
        // Fresh tensors each call: that execution was a prepare miss.
        assert_eq!(backend.stats().prepare_misses, 1);
        assert_eq!(backend.stats().prepare_hits, 0);
    }

    #[test]
    fn native_executes_matvec_entry_against_dense_oracle_and_counts() {
        let (n, m, d) = (50, 7, 3);
        let mut rng = Pcg64::seeded(53);
        let x = rng.normal_vec_f32(n * d);
        let y = rng.normal_vec_f32(m * d);
        let v = rng.normal_vec_f32(n);
        let mut w = vec![1.0f32; n];
        w[2] = 0.0;
        let h = 0.6f64;

        let mut backend = NativeFlash::new();
        let entry = matvec_entry(n, m, d);
        let inputs = arcs(vec![
            HostTensor::matrix(n, d, x.clone()).unwrap(),
            HostTensor::vec1(w.clone()),
            HostTensor::matrix(m, d, y.clone()).unwrap(),
            HostTensor::vec1(v.clone()),
            HostTensor::scalar(h as f32),
        ]);
        let out = backend.execute(&entry, &inputs).expect("execute");
        assert_eq!(out.outputs[0].shape(), &[m]);
        // Dense oracle: materialize K row by row, multiply naively.
        let inv2h2 = 1.0 / (2.0 * h * h);
        let mut want = vec![0.0f64; m];
        for (q, o) in want.iter_mut().enumerate() {
            for j in 0..n {
                let d2: f64 = (0..d)
                    .map(|k| {
                        let diff =
                            (y[q * d + k] - x[j * d + k]) as f64;
                        diff * diff
                    })
                    .sum();
                *o += w[j] as f64 * v[j] as f64 * (-d2 * inv2h2).exp();
            }
        }
        for (a, b) in out.outputs[0].data().iter().zip(&want) {
            let rel = (*a as f64 - b).abs() / b.abs().max(1e-30);
            assert!(rel < 2e-3, "{a} vs {b} (rel {rel:.2e})");
        }
        assert_eq!(backend.stats().matvec_queries, 1);
        assert_eq!(backend.stats().executions, 1);

        // A v whose length disagrees with the train bucket is a typed
        // error, never a kernel panic.
        let mut bad = inputs.clone();
        bad[3] = Arc::new(HostTensor::vec1(vec![1.0f32; n - 1]));
        let mut torn = matvec_entry(n, m, d);
        torn.inputs[3].shape = vec![n - 1];
        let err = backend.execute(&torn, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("entries"), "{err:#}");
        assert_eq!(
            backend.stats().matvec_queries,
            1,
            "a rejected call must not count as served"
        );
    }

    #[test]
    fn matvec_shares_the_prepare_cache_with_density_pipelines() {
        // A resident model prepared by a density query must be a prepare
        // hit for a matvec query over the same tensors — one prepared
        // form serves every pipeline family.
        let (n, m, d) = (48, 5, 2);
        let mut rng = Pcg64::seeded(59);
        let x = Arc::new(
            HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap(),
        );
        let w = Arc::new(HostTensor::full(vec![n], 1.0));
        let y = Arc::new(
            HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap(),
        );
        let v = Arc::new(HostTensor::vec1(rng.normal_vec_f32(n)));
        let h = Arc::new(HostTensor::scalar(0.5));
        let mut backend = NativeFlash::new();
        backend
            .execute(
                &kde_entry(n, m, d),
                &[Arc::clone(&x), Arc::clone(&w), Arc::clone(&y), Arc::clone(&h)],
            )
            .expect("kde");
        backend
            .execute(
                &matvec_entry(n, m, d),
                &[x, w, y, v, h],
            )
            .expect("matvec");
        assert_eq!(backend.stats().prepare_misses, 1);
        assert_eq!(backend.stats().prepare_hits, 1);
    }

    #[test]
    fn prepare_cache_hits_resident_tensors_and_never_changes_results() {
        let (n, m, d) = (64, 8, 3);
        let mut rng = Pcg64::seeded(17);
        let entry = kde_entry(n, m, d);
        // Two "resident models" sharing a backend, as in serving.
        let x1 = Arc::new(HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap());
        let x2 = Arc::new(HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap());
        let w = Arc::new(HostTensor::full(vec![n], 1.0));
        let y = Arc::new(HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap());
        let h = Arc::new(HostTensor::scalar(0.6));

        let mut cached = NativeFlash::new();
        let run = |b: &mut NativeFlash, x: &Arc<HostTensor>| {
            let inputs = vec![
                Arc::clone(x),
                Arc::clone(&w),
                Arc::clone(&y),
                Arc::clone(&h),
            ];
            b.execute(&entry, &inputs).expect("execute").outputs.remove(0)
        };
        // Interleave the two models; from the second touch on, each is a
        // cache hit — and every output must be bitwise what a fresh
        // backend (fresh prepare) computes.
        for round in 0..3 {
            for x in [&x1, &x2] {
                let got = run(&mut cached, x);
                let fresh = run(&mut NativeFlash::new(), x);
                assert_eq!(got, fresh, "round {round}: cache changed a result");
            }
        }
        let s = cached.stats();
        assert_eq!(s.prepare_misses, 2, "one miss per model");
        assert_eq!(s.prepare_hits, 4, "every later touch hits");
        assert_eq!(cached.prepared_len(), 2);
    }

    #[test]
    fn prepare_cache_capacity_is_configurable_with_lru_eviction_at_the_bound() {
        // ISSUE 4 satellite: the cache is sized from `registry_capacity`
        // (via BackendKind::open), not the fixed 64-slot cap.  Pin the
        // eviction order at a small bound: a hit must refresh its slot,
        // so filling past capacity evicts the least-recently-used model,
        // never the hottest one.
        let (n, m, d) = (24, 2, 1);
        let entry = kde_entry(n, m, d);
        let mut rng = Pcg64::seeded(41);
        let mut backend =
            NativeFlash::with_tile_and_capacity(TileConfig::default(), 2);
        assert_eq!(backend.prepare_capacity(), 2);
        // Zero caps clamp instead of panicking on evict.
        assert_eq!(
            NativeFlash::with_tile_and_capacity(TileConfig::default(), 0)
                .prepare_capacity(),
            1
        );

        let model = |rng: &mut Pcg64| {
            (
                Arc::new(HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap()),
                Arc::new(HostTensor::full(vec![n], 1.0)),
            )
        };
        let (xa, wa) = model(&mut rng);
        let (xb, wb) = model(&mut rng);
        let (xc, wc) = model(&mut rng);
        let y = Arc::new(HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap());
        let h = Arc::new(HostTensor::scalar(0.5));
        let run = |b: &mut NativeFlash, x: &Arc<HostTensor>, w: &Arc<HostTensor>| {
            let inputs =
                vec![Arc::clone(x), Arc::clone(w), Arc::clone(&y), Arc::clone(&h)];
            b.execute(&entry, &inputs).expect("execute");
        };

        run(&mut backend, &xa, &wa); // miss: cache [a]
        run(&mut backend, &xb, &wb); // miss: cache [a, b]
        run(&mut backend, &xa, &wa); // hit refreshes a: LRU order [b, a]
        run(&mut backend, &xc, &wc); // miss at capacity: evicts b, NOT a
        assert_eq!(backend.prepared_len(), 2);
        assert_eq!(backend.stats().prepare_misses, 3);
        assert_eq!(backend.stats().prepare_hits, 1);

        run(&mut backend, &xa, &wa); // a survived the eviction: hit
        assert_eq!(backend.stats().prepare_hits, 2, "LRU evicted the hot model");
        run(&mut backend, &xb, &wb); // b was the LRU victim: miss again
        assert_eq!(backend.stats().prepare_misses, 4, "b should have been evicted");
    }

    #[test]
    fn prepare_cache_drops_entry_when_model_tensors_are_released() {
        let (n, m, d) = (32, 4, 2);
        let mut rng = Pcg64::seeded(23);
        let entry = kde_entry(n, m, d);
        let x = Arc::new(HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap());
        let w = Arc::new(HostTensor::full(vec![n], 1.0));

        let mut backend = NativeFlash::new();
        let inputs = vec![
            Arc::clone(&x),
            Arc::clone(&w),
            Arc::new(HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap()),
            Arc::new(HostTensor::scalar(0.5)),
        ];
        backend.execute(&entry, &inputs).expect("execute");
        drop(inputs);
        assert_eq!(backend.prepared_len(), 1);

        // The cache holds only Weaks: releasing the model (registry
        // delete / eviction) must actually free it...
        let x_obs = Arc::downgrade(&x);
        drop(x);
        drop(w);
        assert!(x_obs.upgrade().is_none(), "cache kept the model alive");
        // ...and the slot disappears on the next cache touch.
        backend.prepared_gc();
        assert_eq!(backend.prepared_len(), 0);
    }

    #[test]
    fn native_rejects_bad_shapes_unknown_pipelines_and_dead_weights() {
        let mut backend = NativeFlash::new();
        let entry = kde_entry(4, 2, 1);

        // Arity.
        let err = backend
            .execute(&entry, &arcs(vec![HostTensor::scalar(1.0)]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("expects"), "{err:#}");

        // All-zero weights.
        let err = backend
            .execute(
                &entry,
                &arcs(vec![
                    HostTensor::zeros(vec![4, 1]),
                    HostTensor::zeros(vec![4]),
                    HostTensor::zeros(vec![2, 1]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("no effective samples"), "{err:#}");

        // Unknown pipeline.
        let mut weird = kde_entry(4, 2, 1);
        weird.pipeline = "warp".into();
        let mut w = HostTensor::zeros(vec![4]);
        w.data_mut().fill(1.0);
        let err = backend
            .execute(
                &weird,
                &arcs(vec![
                    HostTensor::zeros(vec![4, 1]),
                    w,
                    HostTensor::zeros(vec![2, 1]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("warp"), "{err:#}");

        // Entries whose own specs are internally inconsistent — ways
        // validate_inputs cannot catch — must be typed errors, never
        // worker panics (the kernels would assert on all three).

        // Train shape vs weights disagree.
        let mut torn = kde_entry(4, 2, 1);
        torn.inputs[0].shape = vec![3, 1];
        let mut w = HostTensor::zeros(vec![4]);
        w.data_mut().fill(1.0);
        let err = backend
            .execute(
                &torn,
                &arcs(vec![
                    HostTensor::zeros(vec![3, 1]),
                    w,
                    HostTensor::zeros(vec![2, 1]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("disagree"), "{err:#}");

        // Query width not a multiple of d.
        let mut torn_y = kde_entry(4, 2, 2);
        torn_y.inputs[2].shape = vec![3];
        let mut w = HostTensor::zeros(vec![4]);
        w.data_mut().fill(1.0);
        let err = backend
            .execute(
                &torn_y,
                &arcs(vec![
                    HostTensor::zeros(vec![4, 2]),
                    w,
                    HostTensor::zeros(vec![3]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("not a multiple"), "{err:#}");

        // Zero dimension.
        let mut torn_d = kde_entry(4, 2, 1);
        torn_d.d = 0;
        torn_d.inputs[0].shape = vec![4, 0];
        torn_d.inputs[2].shape = vec![2, 0];
        let mut w = HostTensor::zeros(vec![4]);
        w.data_mut().fill(1.0);
        let err = backend
            .execute(
                &torn_d,
                &arcs(vec![
                    HostTensor::zeros(vec![4, 0]),
                    w,
                    HostTensor::zeros(vec![2, 0]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("dimension"), "{err:#}");
    }

    #[test]
    fn warm_is_a_noop() {
        let mut backend = NativeFlash::new();
        let d = backend.warm(&kde_entry(4, 2, 1)).unwrap();
        assert_eq!(d, Duration::default());
        assert_eq!(backend.stats().compiles, 0);
    }

    #[test]
    fn resolve_manifest_synthesizes_for_native_only() {
        let missing = std::path::Path::new("/nonexistent-flash-sdkde-dir");
        assert!(resolve_manifest(BackendKind::Pjrt, missing).is_err());
        let m = resolve_manifest(BackendKind::Native, missing).unwrap();
        assert!(!m.entries().is_empty());
    }

    #[test]
    fn prepare_cache_is_shared_across_backend_instances() {
        // ISSUE 5 satellite: every native worker of one engine clones
        // the same PrepareCache, so a model prepared by one worker is a
        // hit for its siblings — and serves the identical prepared form.
        let (n, m, d) = (48, 4, 2);
        let mut rng = Pcg64::seeded(31);
        let entry = kde_entry(n, m, d);
        let x = Arc::new(HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap());
        let w = Arc::new(HostTensor::full(vec![n], 1.0));
        let inputs = vec![
            Arc::clone(&x),
            Arc::clone(&w),
            Arc::new(HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap()),
            Arc::new(HostTensor::scalar(0.5)),
        ];

        let cache = PrepareCache::new(8);
        let mut worker_a =
            NativeFlash::with_cache(TileConfig::default(), cache.clone(), None);
        let mut worker_b =
            NativeFlash::with_cache(TileConfig::default(), cache, None);

        let out_a = worker_a.execute(&entry, &inputs).expect("worker a");
        let out_b = worker_b.execute(&entry, &inputs).expect("worker b");
        assert_eq!(out_a.outputs, out_b.outputs);
        // Counters are cache-wide, so BOTH workers report the engine
        // truth: one miss total (worker b reused the shared slot), one
        // hit — whichever worker a stats request samples.
        for w in [&worker_a, &worker_b] {
            assert_eq!(w.stats().prepare_misses, 1, "shared slot re-prepared");
            assert_eq!(w.stats().prepare_hits, 1);
        }
        assert_eq!(worker_a.prepared_len(), 1);
        assert_eq!(worker_b.prepared_len(), 1, "one cache, one slot");
    }

    #[test]
    fn approx_execute_serves_kde_within_budget_and_counts() {
        use crate::approx::ApproxParams;
        let (n, m, d) = (600, 8, 3);
        let mut rng = Pcg64::seeded(7);
        let x = rng.normal_vec_f32(n * d);
        let y = rng.normal_vec_f32(m * d);
        let w = vec![1.0f32; n];
        let h = 0.5f64;
        let entry = kde_entry(n, m, d);
        let inputs = arcs(vec![
            HostTensor::matrix(n, d, x.clone()).unwrap(),
            HostTensor::vec1(w.clone()),
            HostTensor::matrix(m, d, y.clone()).unwrap(),
            HostTensor::scalar(h as f32),
        ]);
        let params = ApproxParams { rel_err: 0.1, seed: 99, row_offset: 0 };

        let mut backend = NativeFlash::new();
        let out = served(
            backend
                .execute_approx(&entry, &inputs, &params)
                .expect("approx execute"),
        );
        assert_eq!(out.outputs[0].shape(), &[m]);
        let exact = native::kde(&x, &w, &y, d, h);
        for (a, b) in out.outputs[0].data().iter().zip(&exact) {
            let rel = (*a as f64 - b).abs() / b.abs().max(1e-30);
            assert!(rel <= params.rel_err, "{a} vs {b} (rel {rel:.3e})");
        }
        let s = backend.stats();
        assert_eq!(s.approx_queries, 1);
        assert_eq!(s.unsupported_mode, 0);
        assert_eq!(s.executions, 1);

        // Bitwise-stable on repeat; the second call reuses the cached
        // index (one prepare miss total).
        let again = served(
            backend
                .execute_approx(&entry, &inputs, &params)
                .expect("approx again"),
        );
        assert_eq!(again.outputs, out.outputs);
        assert_eq!(backend.stats().prepare_misses, 1);
        assert_eq!(backend.stats().prepare_hits, 1);
    }

    #[test]
    fn approx_is_chunk_invariant_via_row_offset() {
        use crate::approx::ApproxParams;
        let (n, d) = (400, 2);
        let mut rng = Pcg64::seeded(13);
        let x = rng.normal_vec_f32(n * d);
        let y = rng.normal_vec_f32(8 * d);
        let w = vec![1.0f32; n];
        let xs = Arc::new(HostTensor::matrix(n, d, x).unwrap());
        let ws = Arc::new(HostTensor::vec1(w));
        let h = Arc::new(HostTensor::scalar(0.5));
        let run = |b: &mut NativeFlash, rows: &[f32], m: usize, off: usize| {
            let inputs = vec![
                Arc::clone(&xs),
                Arc::clone(&ws),
                Arc::new(HostTensor::matrix(m, d, rows.to_vec()).unwrap()),
                Arc::clone(&h),
            ];
            let params =
                ApproxParams { rel_err: 0.1, seed: 5, row_offset: off };
            served(
                b.execute_approx(&kde_entry(n, m, d), &inputs, &params)
                    .expect("approx"),
            )
            .outputs
            .remove(0)
        };
        let mut backend = NativeFlash::new();
        let whole = run(&mut backend, &y, 8, 0);
        let first = run(&mut backend, &y[..5 * d], 5, 0);
        let rest = run(&mut backend, &y[5 * d..], 3, 5);
        let stitched: Vec<f32> = first
            .data()
            .iter()
            .chain(rest.data())
            .copied()
            .collect();
        assert_eq!(whole.data(), &stitched[..], "chunking moved a result");
    }

    #[test]
    fn approx_declines_non_kde_pipelines_as_counted_fallback() {
        use crate::approx::ApproxParams;
        let mut backend = NativeFlash::new();
        let mut entry = kde_entry(4, 2, 1);
        entry.pipeline = "score_eval".into();
        let params = ApproxParams { rel_err: 0.1, seed: 0, row_offset: 0 };
        let out = backend
            .execute_approx(&entry, &[], &params)
            .expect("an unsupported mode is not an error");
        assert!(matches!(out, ApproxOffer::Unsupported));
        assert_eq!(backend.stats().unsupported_mode, 1);
        assert_eq!(backend.stats().approx_queries, 0);
        // The default trait impl (non-native backends) declines outright
        // — a distinct outcome the coordinator counts separately.
        struct Nop;
        impl ExecBackend for Nop {
            fn execute(
                &mut self,
                _: &ArtifactEntry,
                _: &[Arc<HostTensor>],
            ) -> Result<ExecOutput> {
                unreachable!()
            }
            fn warm(&mut self, _: &ArtifactEntry) -> Result<Duration> {
                Ok(Duration::default())
            }
            fn stats(&self) -> StoreStats {
                StoreStats::default()
            }
            fn cached_len(&self) -> usize {
                0
            }
            fn platform(&self) -> String {
                "nop".into()
            }
        }
        let kde = kde_entry(4, 2, 1);
        assert!(matches!(
            Nop.execute_approx(&kde, &[], &params).unwrap(),
            ApproxOffer::Declined
        ));
    }

    #[test]
    fn sketch_cache_is_bounded_lru_and_counts_evictions() {
        use crate::approx::ApproxParams;
        let (n, m, d) = (600, 4, 2);
        let mut rng = Pcg64::seeded(11);
        let entry = kde_entry(n, m, d);
        let inputs = arcs(vec![
            HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap(),
            HostTensor::vec1(vec![1.0f32; n]),
            HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap(),
            HostTensor::scalar(0.5),
        ]);
        let mut backend = NativeFlash::new();
        let query = |b: &mut NativeFlash, rel_err: f64| {
            let params = ApproxParams { rel_err, seed: 3, row_offset: 0 };
            served(
                b.execute_approx(&entry, &inputs, &params)
                    .expect("approx execute"),
            );
        };

        // A hot budget, touched before each step of a budget sweep that
        // overflows the bound: LRU keeps it resident, so the sweep evicts
        // exactly its own cold tail.
        let hot = 0.10f64;
        query(&mut backend, hot);
        for i in 0..MAX_SKETCHES_PER_MODEL {
            query(&mut backend, hot); // refresh → never the LRU victim
            query(&mut backend, 0.20 + 0.01 * i as f64);
        }
        // 1 hot + MAX sweep entries = MAX + 1 distinct budgets, bound MAX
        // → exactly one eviction so far, and it was not the hot budget.
        assert_eq!(backend.stats().sketch_evictions, 1);
        let before = backend.stats().approx_queries;
        query(&mut backend, hot);
        // The hot budget was still cached: re-querying it probes the
        // cache, evicting nothing new.
        assert_eq!(backend.stats().sketch_evictions, 1);
        assert_eq!(backend.stats().approx_queries, before + 1);
        // An (MAX+2)'th distinct budget evicts again — the bound holds.
        query(&mut backend, 0.4);
        assert_eq!(backend.stats().sketch_evictions, 2);
    }

    #[test]
    fn tuning_table_drives_the_tile_choice_without_moving_results() {
        use crate::tuner::{TunedCell, TuningTable};
        let (n, m, d) = (64, 8, 2);
        let mut rng = Pcg64::seeded(37);
        let entry = kde_entry(n, m, d);
        let x = Arc::new(HostTensor::matrix(n, d, rng.normal_vec_f32(n * d)).unwrap());
        let w = Arc::new(HostTensor::full(vec![n], 1.0));
        let inputs = vec![
            Arc::clone(&x),
            Arc::clone(&w),
            Arc::new(HostTensor::matrix(m, d, rng.normal_vec_f32(m * d)).unwrap()),
            Arc::new(HostTensor::scalar(0.6)),
        ];
        // A cell with deliberately odd block shapes (≠ default), matched
        // by nearest-bucket lookup for this (d, n, m).
        let table = Arc::new(
            TuningTable::new(vec![TunedCell {
                d,
                n: 64,
                m: 8,
                block_q: 3,
                block_t: 17,
                threads: 1,
                simd: false,
                best_ms: 0.1,
                default_ms: 0.2,
            }])
            .unwrap(),
        );
        // Pin simd off on both sides: on the auto-vec path block shapes
        // are bitwise result-invariant (flash.rs), so the tuned backend
        // must produce exactly the untuned output.
        let base = TileConfig::scalar_tiles();
        let mut tuned = NativeFlash::with_cache(
            base,
            PrepareCache::new(4),
            Some(Arc::clone(&table)),
        );
        let mut untuned =
            NativeFlash::with_cache(base, PrepareCache::new(4), None);

        let got = tuned.execute(&entry, &inputs).expect("tuned");
        let want = untuned.execute(&entry, &inputs).expect("untuned");
        assert_eq!(got.outputs, want.outputs, "tuned tile moved a result");
        assert_eq!(tuned.stats().tuned_lookups, 1);
        assert_eq!(tuned.stats().tuned_fallbacks, 0);
        // No table -> neither counter moves.
        assert_eq!(untuned.stats().tuned_lookups, 0);
        assert_eq!(untuned.stats().tuned_fallbacks, 0);

        // Second touch: prepare hit, choice served from the slot — the
        // lookup counter must NOT move again (zero hot-path lookups).
        tuned.execute(&entry, &inputs).expect("tuned again");
        assert_eq!(tuned.stats().tuned_lookups, 1);
        assert_eq!(tuned.stats().prepare_hits, 1);

        // A dimension the table has no cell for is a counted fallback.
        let (n2, m2, d2) = (32, 4, 3);
        let entry2 = kde_entry(n2, m2, d2);
        let inputs2 = vec![
            Arc::new(HostTensor::matrix(n2, d2, rng.normal_vec_f32(n2 * d2)).unwrap()),
            Arc::new(HostTensor::full(vec![n2], 1.0)),
            Arc::new(HostTensor::matrix(m2, d2, rng.normal_vec_f32(m2 * d2)).unwrap()),
            Arc::new(HostTensor::scalar(0.5)),
        ];
        tuned.execute(&entry2, &inputs2).expect("fallback execute");
        assert_eq!(tuned.stats().tuned_fallbacks, 1);
        assert_eq!(tuned.stats().tuned_lookups, 1);
    }
}
