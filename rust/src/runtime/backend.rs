//! Execution backends: one trait, two substrates.
//!
//! [`ExecBackend`] is the contract an engine worker drives: execute an
//! [`ArtifactEntry`] against host tensors, pre-warm entries, report cache
//! stats.  Two implementations exist (DESIGN.md §10):
//!
//! * [`crate::runtime::ExecutableStore`] — the PJRT/XLA path: compiles the
//!   AOT-lowered HLO artifacts and runs them on the XLA CPU client.
//!   Requires `make artifacts` and the `pjrt` cargo feature (which links
//!   the prebuilt `xla_extension`).
//! * [`NativeFlash`] — a pure-Rust backend implementing the same pipelines
//!   with the paper's matmul reordering ([`crate::estimator::flash`]):
//!   blocked f32 dot tiles, f64 row accumulators, query blocks spread over
//!   scoped threads.  Needs no artifacts, no Python, no XLA — the entire
//!   serving path (fit → debias → registry → co-batching → eval/grad →
//!   backpressure) runs on a fresh checkout.
//!
//! Both backends execute against the *same* bucket/manifest shapes, so the
//! coordinator, batcher, wire protocol and every example behave
//! identically on either; when no artifacts exist the native path serves a
//! synthesized manifest ([`crate::runtime::Manifest::synthetic`]).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::artifact::{ArtifactEntry, Manifest};
use super::tensor::HostTensor;
use crate::estimator::flash::{self, TileConfig};
use crate::util::timer::PhaseTimer;

/// Result of one artifact execution (either backend).
#[derive(Debug)]
pub struct ExecOutput {
    pub outputs: Vec<HostTensor>,
    /// Phases: "h2d" / "execute" / "d2h" (+ "compile" on a PJRT cache
    /// miss); the native backend reports a single "execute" phase.
    pub timings: PhaseTimer,
}

/// Cache statistics for the info command / metrics endpoint.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StoreStats {
    pub compiles: u64,
    pub hits: u64,
    pub executions: u64,
    pub compile_time: Duration,
}

/// What an engine worker drives.  Implementations are single-thread
/// objects (PJRT handles are not `Send`); each worker constructs its own
/// via [`BackendKind::open`] on its own thread.
pub trait ExecBackend {
    /// Execute an artifact entry with validated host tensors.
    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[Arc<HostTensor>]) -> Result<ExecOutput>;

    /// Pre-warm an entry (compile for PJRT; no-op for native).
    fn warm(&mut self, entry: &ArtifactEntry) -> Result<Duration>;

    fn stats(&self) -> StoreStats;

    /// Number of compiled executables resident (0 for native).
    fn cached_len(&self) -> usize;

    /// Human-readable substrate name for logs.
    fn platform(&self) -> String;
}

/// Which execution backend serves requests (`backend = pjrt | native` in
/// the config file, `--backend` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// AOT-compiled XLA artifacts via PJRT (requires `make artifacts`).
    #[default]
    Pjrt,
    /// Pure-Rust tiled flash kernels (no artifacts required).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" | "xla" => Some(Self::Pjrt),
            "native" | "native-flash" | "cpu" => Some(Self::Native),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Pjrt => "pjrt",
            Self::Native => "native",
        }
    }

    /// Construct the backend on the calling thread.  `manifest` is kept by
    /// the PJRT store for artifact paths; the native backend needs only
    /// the entries the engine hands it per request.  `pool_peers` is how
    /// many sibling backends share this machine (engine workers): the
    /// native backend divides its kernel-thread budget by it so a
    /// multi-worker engine does not oversubscribe the cores.
    pub fn open(self, manifest: Manifest, pool_peers: usize) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendKind::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    Ok(Box::new(super::store::ExecutableStore::open(manifest)?))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    let _ = manifest;
                    bail!(
                        "backend \"pjrt\" is unavailable: this binary was built \
                         without the `pjrt` feature — use backend = \"native\" \
                         or rebuild with `--features pjrt`"
                    )
                }
            }
            BackendKind::Native => {
                drop(manifest);
                let threads =
                    (flash::default_threads() / pool_peers.max(1)).max(1);
                Ok(Box::new(NativeFlash::with_tile(TileConfig {
                    threads,
                    ..TileConfig::default()
                })))
            }
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Check inputs against an entry's manifest signature (the wire-order
/// contract with model.py) — shared by both backends.
pub fn validate_inputs<T: std::borrow::Borrow<HostTensor>>(
    entry: &ArtifactEntry,
    inputs: &[T],
) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "artifact {} expects {} inputs, got {}",
            entry.key(),
            entry.inputs.len(),
            inputs.len()
        );
    }
    for (i, (spec, t)) in entry.inputs.iter().zip(inputs).enumerate() {
        let t = t.borrow();
        if spec.shape != t.shape() {
            bail!(
                "input {} ({}) of {}: expected shape {:?}, got {:?}",
                i,
                spec.name,
                entry.key(),
                spec.shape,
                t.shape()
            );
        }
    }
    Ok(())
}

/// The native flash backend: dispatches the manifest pipelines onto the
/// tiled kernels in [`crate::estimator::flash`].
///
/// Numerics policy (DESIGN.md §10): f32 dot tiles, f64 norms and row
/// accumulators, identical formulas and masked-row semantics to the
/// scalar oracle; the conformance suite pins the agreement at rtol ≤ 2e-3
/// (the f32 cross-term rounding, same order as the XLA f32 kernels).
pub struct NativeFlash {
    tile: TileConfig,
    stats: StoreStats,
}

impl NativeFlash {
    pub fn new() -> Self {
        Self::with_tile(TileConfig::default())
    }

    /// Pin tile sizes / thread count (conformance + ablation harnesses).
    pub fn with_tile(tile: TileConfig) -> Self {
        NativeFlash { tile, stats: StoreStats::default() }
    }

    pub fn tile(&self) -> &TileConfig {
        &self.tile
    }

    /// Positional input access with a typed error — validate_inputs only
    /// matches the arity against the *entry*, and a foreign manifest may
    /// declare fewer inputs than a pipeline needs; that must never panic
    /// a worker.
    fn input<'a>(
        inputs: &'a [Arc<HostTensor>],
        idx: usize,
        name: &str,
    ) -> Result<&'a HostTensor> {
        match inputs.get(idx) {
            Some(t) => Ok(t.as_ref()),
            None => bail!(
                "native pipeline needs input {idx} ({name}); entry declares {}",
                inputs.len()
            ),
        }
    }

    fn scalar(inputs: &[Arc<HostTensor>], idx: usize, name: &str) -> Result<f64> {
        let t = Self::input(inputs, idx, name)?;
        if t.len() != 1 {
            bail!("input {idx} ({name}) must be a scalar, got shape {:?}", t.shape());
        }
        Ok(t.data()[0] as f64)
    }
}

impl Default for NativeFlash {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecBackend for NativeFlash {
    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[Arc<HostTensor>]) -> Result<ExecOutput> {
        validate_inputs(entry, inputs)?;
        let d = entry.d;
        let mut timer = PhaseTimer::new();
        let start = Instant::now();

        // Every pipeline shares the (x, w) prefix; kernels treat w == 0 as
        // a masked row exactly like the oracle and the padded buckets.
        let x = Self::input(inputs, 0, "x")?.data();
        let w = Self::input(inputs, 1, "w")?.data();
        if !w.iter().any(|&v| v != 0.0) {
            bail!("artifact {}: no effective samples (all weights zero)", entry.key());
        }

        let output = match entry.pipeline.as_str() {
            "kde" => {
                let y = Self::input(inputs, 2, "y")?.data();
                let h = Self::scalar(inputs, 3, "h")?;
                let dens = flash::kde(x, w, y, d, h, &self.tile);
                HostTensor::vec1(dens.iter().map(|&v| v as f32).collect())
            }
            "laplace" => {
                let y = Self::input(inputs, 2, "y")?.data();
                let h = Self::scalar(inputs, 3, "h")?;
                let dens = flash::laplace(x, w, y, d, h, &self.tile);
                HostTensor::vec1(dens.iter().map(|&v| v as f32).collect())
            }
            "score_eval" => {
                let y = Self::input(inputs, 2, "y")?.data();
                let h = Self::scalar(inputs, 3, "h")?;
                let s = flash::score_at(x, w, y, d, h, &self.tile);
                HostTensor::matrix(
                    y.len() / d,
                    d,
                    s.iter().map(|&v| v as f32).collect(),
                )?
            }
            "sdkde_fit" => {
                let h = Self::scalar(inputs, 2, "h")?;
                let h_s = Self::scalar(inputs, 3, "h_score")?;
                let x_sd = flash::debias(x, w, d, h, h_s, &self.tile);
                HostTensor::matrix(w.len(), d, x_sd)?
            }
            // Not routed by the coordinator (SD-KDE evals run "kde" over
            // the debiased set) but kept for parity with real manifests
            // and direct backend driving (benches, conformance).
            "sdkde_e2e" => {
                let y = Self::input(inputs, 2, "y")?.data();
                let h = Self::scalar(inputs, 3, "h")?;
                let h_s = Self::scalar(inputs, 4, "h_score")?;
                let dens = flash::sdkde(x, w, y, d, h, h_s, &self.tile);
                HostTensor::vec1(dens.iter().map(|&v| v as f32).collect())
            }
            other => bail!(
                "native backend does not implement pipeline {other:?} \
                 (artifact {})",
                entry.key()
            ),
        };

        timer.add("execute", start.elapsed());
        if let Some(spec) = entry.outputs.first() {
            if !spec.shape.is_empty() && spec.shape != output.shape() {
                bail!(
                    "native {} produced shape {:?}, manifest says {:?}",
                    entry.key(),
                    output.shape(),
                    spec.shape
                );
            }
        }
        self.stats.executions += 1;
        Ok(ExecOutput { outputs: vec![output], timings: timer })
    }

    fn warm(&mut self, _entry: &ArtifactEntry) -> Result<Duration> {
        // Nothing to compile: the kernels are this binary.
        Ok(Duration::default())
    }

    fn stats(&self) -> StoreStats {
        self.stats
    }

    fn cached_len(&self) -> usize {
        0
    }

    fn platform(&self) -> String {
        format!(
            "native-cpu (tiles {}x{}, {} threads)",
            self.tile.block_q, self.tile.block_t, self.tile.threads
        )
    }
}

/// Resolve the manifest a backend serves: PJRT always loads the artifact
/// directory; the native backend loads it when present (identical buckets
/// to the compiled path) and synthesizes one otherwise.  A *corrupt*
/// manifest is a typed error for both — silent fallback would mask a torn
/// `make artifacts`.
pub fn resolve_manifest(kind: BackendKind, dir: &std::path::Path) -> Result<Manifest> {
    match kind {
        BackendKind::Pjrt => Manifest::load(dir),
        BackendKind::Native => {
            if dir.join("manifest.json").exists() {
                Manifest::load(dir)
            } else {
                Ok(Manifest::synthetic())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::native;
    use crate::runtime::artifact::TensorSpec;
    use crate::util::rng::Pcg64;

    fn kde_entry(n: usize, m: usize, d: usize) -> ArtifactEntry {
        ArtifactEntry {
            pipeline: "kde".into(),
            variant: "flash".into(),
            d,
            n,
            m,
            tiles: None,
            file: format!("native://kde/flash/d{d}/n{n}/m{m}"),
            inputs: vec![
                TensorSpec { name: "x".into(), shape: vec![n, d] },
                TensorSpec { name: "w".into(), shape: vec![n] },
                TensorSpec { name: "y".into(), shape: vec![m, d] },
                TensorSpec { name: "h".into(), shape: vec![] },
            ],
            outputs: vec![TensorSpec { name: "".into(), shape: vec![m] }],
        }
    }

    fn arcs(ts: Vec<HostTensor>) -> Vec<Arc<HostTensor>> {
        ts.into_iter().map(Arc::new).collect()
    }

    #[test]
    fn backend_kind_parse_round_trip() {
        for k in [BackendKind::Pjrt, BackendKind::Native] {
            assert_eq!(BackendKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(BackendKind::parse("native-flash"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("XLA"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Pjrt);
    }

    #[test]
    fn native_executes_kde_entry_against_oracle() {
        let (n, m, d) = (40, 6, 2);
        let mut rng = Pcg64::seeded(3);
        let x = rng.normal_vec_f32(n * d);
        let y = rng.normal_vec_f32(m * d);
        let w = vec![1.0f32; n];
        let h = 0.55f64;

        let mut backend = NativeFlash::new();
        let entry = kde_entry(n, m, d);
        let out = backend
            .execute(
                &entry,
                &arcs(vec![
                    HostTensor::matrix(n, d, x.clone()).unwrap(),
                    HostTensor::vec1(w.clone()),
                    HostTensor::matrix(m, d, y.clone()).unwrap(),
                    HostTensor::scalar(h as f32),
                ]),
            )
            .expect("execute");
        assert_eq!(out.outputs.len(), 1);
        assert_eq!(out.outputs[0].shape(), &[m]);
        let want = native::kde(&x, &w, &y, d, h);
        for (a, b) in out.outputs[0].data().iter().zip(&want) {
            assert!(((*a as f64 - b) / b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(backend.stats().executions, 1);
        assert_eq!(backend.cached_len(), 0);
        assert!(backend.platform().contains("native-cpu"));
    }

    #[test]
    fn native_rejects_bad_shapes_unknown_pipelines_and_dead_weights() {
        let mut backend = NativeFlash::new();
        let entry = kde_entry(4, 2, 1);

        // Arity.
        let err = backend
            .execute(&entry, &arcs(vec![HostTensor::scalar(1.0)]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("expects"), "{err:#}");

        // All-zero weights.
        let err = backend
            .execute(
                &entry,
                &arcs(vec![
                    HostTensor::zeros(vec![4, 1]),
                    HostTensor::zeros(vec![4]),
                    HostTensor::zeros(vec![2, 1]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("no effective samples"), "{err:#}");

        // Unknown pipeline.
        let mut weird = kde_entry(4, 2, 1);
        weird.pipeline = "warp".into();
        let mut w = HostTensor::zeros(vec![4]);
        w.data_mut().fill(1.0);
        let err = backend
            .execute(
                &weird,
                &arcs(vec![
                    HostTensor::zeros(vec![4, 1]),
                    w,
                    HostTensor::zeros(vec![2, 1]),
                    HostTensor::scalar(0.5),
                ]),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("warp"), "{err:#}");
    }

    #[test]
    fn warm_is_a_noop() {
        let mut backend = NativeFlash::new();
        let d = backend.warm(&kde_entry(4, 2, 1)).unwrap();
        assert_eq!(d, Duration::default());
        assert_eq!(backend.stats().compiles, 0);
    }

    #[test]
    fn resolve_manifest_synthesizes_for_native_only() {
        let missing = std::path::Path::new("/nonexistent-flash-sdkde-dir");
        assert!(resolve_manifest(BackendKind::Pjrt, missing).is_err());
        let m = resolve_manifest(BackendKind::Native, missing).unwrap();
        assert!(!m.entries.is_empty());
    }
}
