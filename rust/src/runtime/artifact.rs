//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `manifest.json` (schema in aot.py's docstring) indexes every lowered HLO
//! artifact by (pipeline, variant, d, n-bucket, m-bucket, tiles).  This
//! module parses it into typed records and answers bucket-selection queries
//! for the coordinator ("smallest bucket that fits n train points and m
//! queries").
//!
//! Bucket queries are answered by a **routing index** built once at
//! construction — groups keyed by (pipeline, variant, d), each holding its
//! (n, m) buckets pre-sorted — instead of scanning the entry list with
//! string compares per request.  On the ~4k-entry synthetic manifest the
//! linear scan was a measurable slice of the smallest native batches
//! (DESIGN.md §11); the in-module regression test pins index and linear
//! scan to identical answers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// One tensor signature in an artifact's I/O list.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Parameter name as lowered (informational; wire order is binding).
    pub name: String,
    /// Static shape; empty means rank-0 scalar.
    pub shape: Vec<usize>,
}

/// One lowered artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Pipeline id (`kde`, `laplace`, `score_eval`, `sdkde_fit`, …).
    pub pipeline: String,
    /// Execution variant (`flash`, `gemm`, `stream`, `naive`, `nonfused`).
    pub variant: String,
    /// Data dimension.
    pub d: usize,
    /// Train-rows bucket.
    pub n: usize,
    /// Query-rows bucket (for fit pipelines this mirrors the plan but is
    /// unused at execution time).
    pub m: usize,
    /// Optional (BLOCK_M, BLOCK_N) tile pin (§6.2 sweep artifacts).
    pub tiles: Option<(usize, usize)>,
    /// File name relative to the artifact directory.
    pub file: String,
    /// Input signatures in wire order.
    pub inputs: Vec<TensorSpec>,
    /// Output signatures in wire order.
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactEntry {
    /// Unique key used by the executable cache.
    pub fn key(&self) -> String {
        match self.tiles {
            Some((bm, bn)) => format!(
                "{}__{}__d{}__n{}__m{}__bm{}__bn{}",
                self.pipeline, self.variant, self.d, self.n, self.m, bm, bn
            ),
            None => format!(
                "{}__{}__d{}__n{}__m{}",
                self.pipeline, self.variant, self.d, self.n, self.m
            ),
        }
    }
}

/// Routing index: entries grouped by (pipeline, variant, d), groups
/// sorted for binary search, each group's (n, m) buckets sorted so exact
/// lookups and smallest-fitting-bucket selection are a partition point
/// plus a short scan.  Tile-pinned sweep entries are excluded, exactly as
/// the linear predicates excluded them.
#[derive(Debug, Clone, Default)]
struct ManifestIndex {
    groups: Vec<IndexGroup>,
}

#[derive(Debug, Clone)]
struct IndexGroup {
    pipeline: String,
    variant: String,
    d: usize,
    /// (n, m, index into `Manifest::entries`), stably sorted by (n, m) —
    /// ties keep manifest order, preserving the linear scan's
    /// first-match semantics for duplicate buckets.
    buckets: Vec<(usize, usize, usize)>,
}

impl ManifestIndex {
    fn build(entries: &[ArtifactEntry]) -> ManifestIndex {
        let mut groups: Vec<IndexGroup> = Vec::new();
        let mut by_key: HashMap<(String, String, usize), usize> = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            if e.tiles.is_some() {
                continue;
            }
            let key = (e.pipeline.clone(), e.variant.clone(), e.d);
            let gi = *by_key.entry(key).or_insert_with(|| {
                groups.push(IndexGroup {
                    pipeline: e.pipeline.clone(),
                    variant: e.variant.clone(),
                    d: e.d,
                    buckets: Vec::new(),
                });
                groups.len() - 1
            });
            groups[gi].buckets.push((e.n, e.m, i));
        }
        for g in &mut groups {
            // Stable: equal (n, m) keep entry order.
            g.buckets.sort_by_key(|&(n, m, _)| (n, m));
        }
        groups.sort_by(|a, b| {
            (a.pipeline.as_str(), a.variant.as_str(), a.d)
                .cmp(&(b.pipeline.as_str(), b.variant.as_str(), b.d))
        });
        ManifestIndex { groups }
    }

    fn group(&self, pipeline: &str, variant: &str, d: usize) -> Option<&IndexGroup> {
        self.groups
            .binary_search_by(|g| {
                (g.pipeline.as_str(), g.variant.as_str(), g.d)
                    .cmp(&(pipeline, variant, d))
            })
            .ok()
            .map(|i| &self.groups[i])
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory the entries' files are relative to.
    pub dir: PathBuf,
    /// Build digest recorded by aot.py (empty for synthesized manifests).
    pub digest: String,
    /// Private because the routing index holds positions into it: any
    /// post-construction mutation would desynchronize bucket lookups.
    /// Read through [`Manifest::entries`].
    entries: Vec<ArtifactEntry>,
    index: ManifestIndex,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let value = json::parse(&text)
            .map_err(|e| anyhow!("manifest parse error: {e}"))?;
        Self::from_json(dir, &value)
    }

    /// Build from parsed manifest JSON (version-checked, typed errors).
    pub fn from_json(dir: &Path, v: &Value) -> Result<Manifest> {
        let version = v
            .get("version")
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("manifest missing integer 'version'"))?;
        if version != 1 {
            bail!("unsupported manifest version {version} (expected 1)");
        }
        let digest = v
            .get("digest")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string();
        let raw_entries = v
            .get("entries")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("manifest missing 'entries' array"))?;
        let mut entries = Vec::with_capacity(raw_entries.len());
        for (i, e) in raw_entries.iter().enumerate() {
            entries.push(
                parse_entry(e).with_context(|| format!("manifest entry {i}"))?,
            );
        }
        Ok(Self::assemble(dir.to_path_buf(), digest, entries))
    }

    /// The one constructor: every manifest builds its routing index here.
    fn assemble(dir: PathBuf, digest: String, entries: Vec<ArtifactEntry>) -> Manifest {
        let index = ManifestIndex::build(&entries);
        Manifest { dir, digest, entries, index }
    }

    /// Every artifact entry, in manifest order (read-only: the routing
    /// index is built at construction and indexes into this list).
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Synthesized manifest for the native backend when no compiled
    /// artifacts exist: the default bucket schedule (power-of-two train
    /// buckets, a small ladder of query buckets) over every serving
    /// pipeline at the flash variant.  The native backend has no real
    /// shape constraint — the buckets exist so routing, padding, masking
    /// and chunking behave identically to the compiled path.  Dimensions
    /// cover every d up to 32 plus the common wider embeddings; an
    /// out-of-grid d fails fit with the bucket error naming the grid.
    ///
    /// Memoized: the ~4k-entry schedule (and its routing index) is built
    /// once per process and cloned per call — callers (engine boot, every
    /// test coordinator) hold their own copy, so a shared `&'static`
    /// would not fit the `Engine`'s owned-manifest contract.
    pub fn synthetic() -> Manifest {
        static SYNTHETIC: OnceLock<Manifest> = OnceLock::new();
        SYNTHETIC
            .get_or_init(|| {
                let dims: Vec<usize> = (1..=32).chain([48, 64, 128]).collect();
                Self::synthetic_with(
                    &dims,
                    &[64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384],
                    &[32, 128, 512, 2048],
                )
            })
            .clone()
    }

    /// Synthesized manifest over explicit dimension / bucket grids
    /// (tests pin small grids; `synthetic()` is the serving default).
    pub fn synthetic_with(
        dims: &[usize],
        n_buckets: &[usize],
        m_buckets: &[usize],
    ) -> Manifest {
        let spec = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
        };
        let mut entries = Vec::new();
        for &d in dims {
            for &n in n_buckets {
                for &m in m_buckets {
                    let eval_inputs = || {
                        vec![
                            spec("x", vec![n, d]),
                            spec("w", vec![n]),
                            spec("y", vec![m, d]),
                            spec("h", vec![]),
                        ]
                    };
                    for pipeline in ["kde", "laplace"] {
                        entries.push(ArtifactEntry {
                            pipeline: pipeline.to_string(),
                            variant: "flash".to_string(),
                            d,
                            n,
                            m,
                            tiles: None,
                            file: format!("native://{pipeline}/flash/d{d}/n{n}/m{m}"),
                            inputs: eval_inputs(),
                            outputs: vec![spec("", vec![m])],
                        });
                    }
                    entries.push(ArtifactEntry {
                        pipeline: "score_eval".to_string(),
                        variant: "flash".to_string(),
                        d,
                        n,
                        m,
                        tiles: None,
                        file: format!("native://score_eval/flash/d{d}/n{n}/m{m}"),
                        inputs: eval_inputs(),
                        outputs: vec![spec("", vec![m, d])],
                    });
                    // Kernel matrix–vector product: the eval signature
                    // plus a per-request train-side vector v [n] between
                    // y and h (DESIGN.md §17).
                    entries.push(ArtifactEntry {
                        pipeline: "matvec".to_string(),
                        variant: "flash".to_string(),
                        d,
                        n,
                        m,
                        tiles: None,
                        file: format!("native://matvec/flash/d{d}/n{n}/m{m}"),
                        inputs: vec![
                            spec("x", vec![n, d]),
                            spec("w", vec![n]),
                            spec("y", vec![m, d]),
                            spec("v", vec![n]),
                            spec("h", vec![]),
                        ],
                        outputs: vec![spec("", vec![m])],
                    });
                }
                // Fit has no query axis; m = 0 marks it unused.
                entries.push(ArtifactEntry {
                    pipeline: "sdkde_fit".to_string(),
                    variant: "flash".to_string(),
                    d,
                    n,
                    m: 0,
                    tiles: None,
                    file: format!("native://sdkde_fit/flash/d{d}/n{n}"),
                    inputs: vec![
                        spec("x", vec![n, d]),
                        spec("w", vec![n]),
                        spec("h", vec![]),
                        spec("h_score", vec![]),
                    ],
                    outputs: vec![spec("", vec![n, d])],
                });
            }
        }
        Self::assemble(
            PathBuf::from("<native-synthetic>"),
            "native-synthetic".to_string(),
            entries,
        )
    }

    /// Exact lookup (tile-pinned sweep entries never match).
    pub fn find(
        &self,
        pipeline: &str,
        variant: &str,
        d: usize,
        n: usize,
        m: usize,
    ) -> Option<&ArtifactEntry> {
        let g = self.index.group(pipeline, variant, d)?;
        let at = g.buckets.partition_point(|&(bn, bm, _)| (bn, bm) < (n, m));
        match g.buckets.get(at) {
            Some(&(bn, bm, i)) if bn == n && bm == m => Some(&self.entries[i]),
            _ => None,
        }
    }

    /// Smallest bucket with `n >= n_need` and `m >= m_need` for a pipeline
    /// variant and dimension.  This is the coordinator's shape router —
    /// "smallest" prefers tight n first (quadratic cost), then tight m,
    /// which is exactly the group's (n, m) sort order, so the answer is
    /// the first fitting bucket at or after the n partition point.
    pub fn select_bucket(
        &self,
        pipeline: &str,
        variant: &str,
        d: usize,
        n_need: usize,
        m_need: usize,
    ) -> Option<&ArtifactEntry> {
        let g = self.index.group(pipeline, variant, d)?;
        let start = g.buckets.partition_point(|&(bn, _, _)| bn < n_need);
        g.buckets[start..]
            .iter()
            .find(|&&(_, bm, _)| bm >= m_need)
            .map(|&(_, _, i)| &self.entries[i])
    }

    /// All (n, m) buckets available for (pipeline, variant, d), sorted.
    pub fn buckets(
        &self,
        pipeline: &str,
        variant: &str,
        d: usize,
    ) -> Vec<(usize, usize)> {
        match self.index.group(pipeline, variant, d) {
            None => Vec::new(),
            Some(g) => {
                let mut out: Vec<(usize, usize)> =
                    g.buckets.iter().map(|&(n, m, _)| (n, m)).collect();
                out.dedup(); // already sorted by construction
                out
            }
        }
    }

    /// The §6.2 tile-sweep artifacts.
    pub fn sweep_entries(&self) -> Vec<&ArtifactEntry> {
        self.entries.iter().filter(|e| e.tiles.is_some()).collect()
    }

    /// Dimensions present in the manifest (sweep entries included).
    pub fn dims(&self) -> Vec<usize> {
        let mut ds: Vec<usize> = self.entries.iter().map(|e| e.d).collect();
        ds.sort_unstable();
        ds.dedup();
        ds
    }

    // ---- linear reference implementations (regression oracle) ----
    //
    // The pre-index scans, kept verbatim so the test suite can pin the
    // index to identical answers over every entry and probe shape.

    #[cfg(test)]
    fn find_linear(
        &self,
        pipeline: &str,
        variant: &str,
        d: usize,
        n: usize,
        m: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.pipeline == pipeline
                && e.variant == variant
                && e.d == d
                && e.n == n
                && e.m == m
                && e.tiles.is_none()
        })
    }

    #[cfg(test)]
    fn select_bucket_linear(
        &self,
        pipeline: &str,
        variant: &str,
        d: usize,
        n_need: usize,
        m_need: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.pipeline == pipeline
                    && e.variant == variant
                    && e.d == d
                    && e.tiles.is_none()
                    && e.n >= n_need
                    && e.m >= m_need
            })
            .min_by_key(|e| (e.n, e.m))
    }

    #[cfg(test)]
    fn buckets_linear(
        &self,
        pipeline: &str,
        variant: &str,
        d: usize,
    ) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = self
            .entries
            .iter()
            .filter(|e| {
                e.pipeline == pipeline && e.variant == variant && e.d == d
                    && e.tiles.is_none()
            })
            .map(|e| (e.n, e.m))
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

fn parse_specs(v: Option<&Value>, field: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .and_then(Value::as_array)
        .ok_or_else(|| anyhow!("missing '{field}' array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, spec)| {
            let shape = spec
                .get("shape")
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow!("{field}[{i}] missing shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape value")))
                .collect::<Result<Vec<_>>>()?;
            let name = spec
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or("")
                .to_string();
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

fn parse_entry(e: &Value) -> Result<ArtifactEntry> {
    let get_str = |k: &str| -> Result<String> {
        e.get(k)
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("missing string '{k}'"))
    };
    let get_usize = |k: &str| -> Result<usize> {
        e.get(k)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("missing integer '{k}'"))
    };
    let tiles = match e.get("tiles") {
        None | Some(Value::Null) => None,
        Some(Value::Array(a)) if a.len() == 2 => {
            let bm = a[0].as_usize().ok_or_else(|| anyhow!("bad tiles"))?;
            let bn = a[1].as_usize().ok_or_else(|| anyhow!("bad tiles"))?;
            Some((bm, bn))
        }
        Some(other) => bail!("bad 'tiles' value: {other:?}"),
    };
    Ok(ArtifactEntry {
        pipeline: get_str("pipeline")?,
        variant: get_str("variant")?,
        d: get_usize("d")?,
        n: get_usize("n")?,
        m: get_usize("m")?,
        tiles,
        file: get_str("file")?,
        inputs: parse_specs(e.get("inputs"), "inputs")?,
        outputs: parse_specs(e.get("outputs"), "outputs")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> Value {
        json::parse(
            r#"{
          "version": 1,
          "digest": "abc",
          "entries": [
            {"pipeline": "kde", "variant": "flash", "d": 16, "n": 512,
             "m": 64, "tiles": null, "file": "a.hlo.txt",
             "inputs": [{"name": "x", "shape": [512, 16]},
                        {"name": "w", "shape": [512]},
                        {"name": "y", "shape": [64, 16]},
                        {"name": "h", "shape": []}],
             "outputs": [{"shape": [64]}]},
            {"pipeline": "kde", "variant": "flash", "d": 16, "n": 1024,
             "m": 128, "tiles": null, "file": "b.hlo.txt",
             "inputs": [], "outputs": []},
            {"pipeline": "kde", "variant": "flash", "d": 16, "n": 1024,
             "m": 64, "tiles": null, "file": "c.hlo.txt",
             "inputs": [], "outputs": []},
            {"pipeline": "sdkde_fit", "variant": "flash", "d": 16,
             "n": 2048, "m": 256, "tiles": [64, 512], "file": "d.hlo.txt",
             "inputs": [], "outputs": []}
          ]
        }"#,
        )
        .unwrap()
    }

    fn manifest() -> Manifest {
        Manifest::from_json(Path::new("/tmp/art"), &manifest_json()).unwrap()
    }

    #[test]
    fn parses_entries_and_specs() {
        let m = manifest();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.digest, "abc");
        let e = &m.entries[0];
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[0].name, "x");
        assert_eq!(e.inputs[0].shape, vec![512, 16]);
        assert_eq!(e.inputs[3].shape, Vec::<usize>::new());
        assert_eq!(e.outputs[0].shape, vec![64]);
        assert_eq!(m.path_of(e), Path::new("/tmp/art/a.hlo.txt"));
    }

    #[test]
    fn exact_find_skips_tile_pinned() {
        let m = manifest();
        assert!(m.find("kde", "flash", 16, 512, 64).is_some());
        assert!(m.find("sdkde_fit", "flash", 16, 2048, 256).is_none());
        assert!(m.find("kde", "gemm", 16, 512, 64).is_none());
    }

    #[test]
    fn bucket_selection_prefers_tight_n_then_m() {
        let m = manifest();
        // Fits in 512/64 exactly.
        let e = m.select_bucket("kde", "flash", 16, 300, 60).unwrap();
        assert_eq!((e.n, e.m), (512, 64));
        // Needs n > 512 -> 1024; m <= 64 -> the tighter m bucket.
        let e = m.select_bucket("kde", "flash", 16, 600, 30).unwrap();
        assert_eq!((e.n, e.m), (1024, 64));
        // Needs m > 64 -> 1024/128.
        let e = m.select_bucket("kde", "flash", 16, 600, 100).unwrap();
        assert_eq!((e.n, e.m), (1024, 128));
        // Too big for any bucket.
        assert!(m.select_bucket("kde", "flash", 16, 5000, 64).is_none());
    }

    #[test]
    fn buckets_listing() {
        let m = manifest();
        assert_eq!(
            m.buckets("kde", "flash", 16),
            vec![(512, 64), (1024, 64), (1024, 128)]
        );
        assert!(m.buckets("kde", "naive", 16).is_empty());
    }

    #[test]
    fn sweep_entries_and_keys() {
        let m = manifest();
        let sweep = m.sweep_entries();
        assert_eq!(sweep.len(), 1);
        assert_eq!(sweep[0].tiles, Some((64, 512)));
        assert!(sweep[0].key().ends_with("__bm64__bn512"));
        assert_eq!(m.entries[0].key(), "kde__flash__d16__n512__m64");
    }

    #[test]
    fn rejects_bad_version_and_schema() {
        let v = json::parse(r#"{"version": 2, "entries": []}"#).unwrap();
        assert!(Manifest::from_json(Path::new("."), &v).is_err());
        let v = json::parse(r#"{"version": 1}"#).unwrap();
        assert!(Manifest::from_json(Path::new("."), &v).is_err());
        let v = json::parse(
            r#"{"version": 1, "entries": [{"pipeline": "kde"}]}"#,
        )
        .unwrap();
        assert!(Manifest::from_json(Path::new("."), &v).is_err());
    }

    #[test]
    fn dims_listing() {
        assert_eq!(manifest().dims(), vec![16]);
    }

    #[test]
    fn synthetic_manifest_covers_serving_pipelines() {
        let m = Manifest::synthetic();
        // Every pipeline the coordinator can route (SD-KDE evals run the
        // kde pipeline over the debiased set, so no sdkde_e2e needed).
        for d in [1, 5, 16, 31, 64] {
            for pipeline in ["kde", "laplace", "score_eval", "sdkde_fit", "matvec"] {
                assert!(
                    !m.buckets(pipeline, "flash", d).is_empty(),
                    "no {pipeline} buckets at d={d}"
                );
            }
            // Fit and eval share train buckets (the coordinator intersects
            // them for SD-KDE; an empty intersection would break fit).
            let fit_ns: Vec<usize> =
                m.buckets("sdkde_fit", "flash", d).iter().map(|&(n, _)| n).collect();
            let eval_ns: Vec<usize> =
                m.buckets("kde", "flash", d).iter().map(|&(n, _)| n).collect();
            assert!(fit_ns.iter().all(|n| eval_ns.contains(n)));
        }
        // The router picks tight buckets out of the synthetic schedule.
        let e = m.select_bucket("kde", "flash", 16, 300, 60).unwrap();
        assert_eq!((e.n, e.m), (512, 128));
        assert!(m.sweep_entries().is_empty());
    }

    #[test]
    fn synthetic_is_memoized_and_stable() {
        let a = Manifest::synthetic();
        let b = Manifest::synthetic();
        assert_eq!(a.digest, "native-synthetic");
        assert_eq!(a.entries, b.entries, "memoized clone must be identical");
        assert_eq!(a.dims(), b.dims());
    }

    /// The tentpole regression gate: the routing index must answer every
    /// probe exactly like the linear scan it replaced — exact finds,
    /// smallest-fitting-bucket selection (including the tie-breaking
    /// order) and bucket listings, over every entry of the full synthetic
    /// manifest plus off-grid probes.
    #[test]
    fn index_agrees_with_linear_scan_on_every_synthetic_entry() {
        let m = Manifest::synthetic();
        assert!(m.entries.len() > 1000, "synthetic should be ~4k entries");
        for e in &m.entries {
            // Exact find: same entry (pointer-level) both ways.
            let a = m.find(&e.pipeline, &e.variant, e.d, e.n, e.m);
            let b = m.find_linear(&e.pipeline, &e.variant, e.d, e.n, e.m);
            assert_eq!(a, b, "find disagrees at {}", e.key());
            assert!(a.is_some(), "find lost {}", e.key());

            // Selection probes around each bucket: exact fit, one under
            // (same answer), one over (next bucket or none).
            for (nn, mn) in [
                (e.n, e.m),
                (e.n.saturating_sub(1), e.m.saturating_sub(1)),
                (e.n + 1, e.m),
                (e.n, e.m + 1),
            ] {
                let a = m.select_bucket(&e.pipeline, &e.variant, e.d, nn, mn);
                let b = m.select_bucket_linear(&e.pipeline, &e.variant, e.d, nn, mn);
                assert_eq!(
                    a, b,
                    "select_bucket disagrees at {} need=({nn},{mn})",
                    e.key()
                );
            }
        }
        // Bucket listings per routed group, plus groups that don't exist.
        for d in [0, 1, 16, 33, 64, 128, 129] {
            for pipeline in
                ["kde", "laplace", "score_eval", "sdkde_fit", "matvec", "warp"]
            {
                for variant in ["flash", "gemm", "nope"] {
                    assert_eq!(
                        m.buckets(pipeline, variant, d),
                        m.buckets_linear(pipeline, variant, d),
                        "buckets disagree for {pipeline}/{variant}/d{d}"
                    );
                }
            }
        }
    }

    #[test]
    fn index_survives_duplicate_buckets_with_first_match_semantics() {
        // Two non-tile entries with the same key shape: both find and
        // select must return the *first* in manifest order, like the
        // linear scan did.
        let v = json::parse(
            r#"{
          "version": 1,
          "entries": [
            {"pipeline": "kde", "variant": "flash", "d": 2, "n": 64,
             "m": 32, "tiles": null, "file": "first.hlo.txt",
             "inputs": [], "outputs": []},
            {"pipeline": "kde", "variant": "flash", "d": 2, "n": 64,
             "m": 32, "tiles": null, "file": "second.hlo.txt",
             "inputs": [], "outputs": []}
          ]
        }"#,
        )
        .unwrap();
        let m = Manifest::from_json(Path::new("."), &v).unwrap();
        assert_eq!(m.find("kde", "flash", 2, 64, 32).unwrap().file, "first.hlo.txt");
        assert_eq!(
            m.select_bucket("kde", "flash", 2, 1, 1).unwrap().file,
            "first.hlo.txt"
        );
        assert_eq!(m.buckets("kde", "flash", 2), vec![(64, 32)]);
    }
}
