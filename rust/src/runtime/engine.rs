//! Engine: threaded execution front-end over an
//! [`ExecBackend`](super::backend::ExecBackend).
//!
//! Each engine worker thread owns its own backend instance — a PJRT
//! `ExecutableStore` (whose handles are not `Send`) or a `NativeFlash`
//! kernel runner, selected by [`BackendKind`] — and drains a shared job
//! queue.  The `Engine` handle is cheap to clone and safe to share across
//! the coordinator's connection threads — this is the boundary between the
//! L3 request path and the execution substrate, analogous to a GPU-stream
//! owner thread in a serving stack.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::artifact::{ArtifactEntry, Manifest};
use super::backend::{
    ApproxOffer, BackendKind, ExecBackend as _, ExecOutput, PrepareCache,
    StoreStats,
};
use super::tensor::HostTensor;
use crate::approx::ApproxParams;
use crate::log_info;
use crate::tuner::TuningTable;

/// What to execute: an exact artifact entry (resolved by the caller via the
/// shared `Manifest`, which is plain data and freely shareable).
#[derive(Debug, Clone)]
pub struct ExecRequest {
    /// The resolved artifact entry to execute.
    pub entry: ArtifactEntry,
    /// Arc-shared so registry-resident tensors (the fitted training set)
    /// cross into the worker without copying (perf pass, EXPERIMENTS.md).
    pub inputs: Vec<Arc<HostTensor>>,
}

enum Job {
    Exec {
        req: ExecRequest,
        reply: Sender<Result<ExecOutput>>,
    },
    ExecApprox {
        req: ExecRequest,
        params: ApproxParams,
        reply: Sender<Result<ApproxOffer>>,
    },
    Warm {
        entries: Vec<ArtifactEntry>,
        reply: Sender<Result<Duration>>,
    },
    Stats {
        reply: Sender<(StoreStats, usize)>,
    },
    Shutdown,
}

/// Cloneable handle to the engine worker pool.
#[derive(Clone)]
pub struct Engine {
    tx: Sender<Job>,
    manifest: Arc<Manifest>,
    backend: BackendKind,
    /// Held only for its Drop: the last handle shuts the workers down.
    #[allow(dead_code)]
    inner: Arc<EngineInner>,
}

struct EngineInner {
    tx: Sender<Job>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for EngineInner {
    fn drop(&mut self) {
        let workers = std::mem::take(&mut *self.workers.lock().expect("poisoned"));
        for _ in &workers {
            let _ = self.tx.send(Job::Shutdown);
        }
        for w in workers {
            let _ = w.join();
        }
    }
}

impl Engine {
    /// Start `workers` threads, each owning its own `backend` instance
    /// (a PJRT client + executable cache, or a native kernel runner).
    /// `prepare_cap` bounds the engine's resident-model prepare cache —
    /// **one cache, shared by every native worker** (the coordinator
    /// passes its registry capacity so every resident model can keep its
    /// prepared form; ignored by PJRT).  `tuning` is the optional
    /// tile-tuning table every native worker consults (`serve --tuning`).
    pub fn start(
        manifest: Manifest,
        workers: usize,
        backend: BackendKind,
        prepare_cap: usize,
        tuning: Option<Arc<TuningTable>>,
    ) -> Result<Engine> {
        assert!(workers >= 1, "engine needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let manifest = Arc::new(manifest);
        let cache = PrepareCache::new(prepare_cap);

        let mut handles = Vec::with_capacity(workers);
        for worker_id in 0..workers {
            let rx = Arc::clone(&rx);
            let manifest = Manifest::clone(&manifest);
            let cache = cache.clone();
            let tuning = tuning.clone();
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let handle = std::thread::Builder::new()
                .name(format!("engine-{worker_id}"))
                .spawn(move || {
                    worker_loop(
                        worker_id, workers, backend, cache, tuning, manifest,
                        rx, ready_tx,
                    )
                })
                .context("spawning engine worker")?;
            // Surface backend-creation failures at startup, not first use.
            ready_rx
                .recv()
                .map_err(|_| anyhow!("engine worker {worker_id} died during init"))??;
            handles.push(handle);
        }
        let inner = Arc::new(EngineInner {
            tx: tx.clone(),
            workers: Mutex::new(handles),
        });
        Ok(Engine { tx, manifest, backend, inner })
    }

    /// The shared artifact manifest (bucket selection happens caller-side).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Which execution backend the workers run.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Execute an artifact; blocks until the result is ready.
    pub fn execute(&self, entry: &ArtifactEntry, inputs: Vec<Arc<HostTensor>>) -> Result<ExecOutput> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Exec {
                req: ExecRequest { entry: entry.clone(), inputs },
                reply,
            })
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine worker dropped reply"))?
    }

    /// Try to execute an artifact through the backend's approximate path
    /// (DESIGN.md §14); blocks until the result is ready.  The non-served
    /// [`ApproxOffer`] outcomes distinguish *why* the backend passed —
    /// `Unsupported` (this pipeline has no approximate estimator) vs
    /// `Declined` (this backend has no approximate path at all) — and in
    /// both cases the caller must fall back to [`execute`](Self::execute).
    pub fn execute_approx(
        &self,
        entry: &ArtifactEntry,
        inputs: Vec<Arc<HostTensor>>,
        params: ApproxParams,
    ) -> Result<ApproxOffer> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::ExecApprox {
                req: ExecRequest { entry: entry.clone(), inputs },
                params,
                reply,
            })
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine worker dropped reply"))?
    }

    /// Pre-compile entries on one worker; returns total compile time.
    pub fn warm(&self, entries: Vec<ArtifactEntry>) -> Result<Duration> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Warm { entries, reply })
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine worker dropped reply"))?
    }

    /// Aggregate store stats from one worker (representative under the
    /// single-worker default; labelled per-worker in logs otherwise).
    pub fn stats(&self) -> Result<(StoreStats, usize)> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Stats { reply })
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine worker dropped reply"))
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_id: usize,
    pool_size: usize,
    backend: BackendKind,
    cache: PrepareCache,
    tuning: Option<Arc<TuningTable>>,
    manifest: Manifest,
    rx: Arc<Mutex<Receiver<Job>>>,
    ready: Sender<Result<()>>,
) {
    let mut store = match backend.open(manifest, pool_size, cache, tuning) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    log_info!("engine", "worker {worker_id} up on {}", store.platform());
    loop {
        // Hold the lock only while dequeueing so workers interleave.
        let job = match rx.lock().expect("engine queue poisoned").recv() {
            Ok(j) => j,
            Err(_) => break, // all senders gone
        };
        match job {
            Job::Exec { req, reply } => {
                let out = store.execute(&req.entry, &req.inputs);
                let _ = reply.send(out);
            }
            Job::ExecApprox { req, params, reply } => {
                let out = store.execute_approx(&req.entry, &req.inputs, &params);
                let _ = reply.send(out);
            }
            Job::Warm { entries, reply } => {
                let mut total = Duration::default();
                let mut result = Ok(());
                for e in &entries {
                    match store.warm(e) {
                        Ok(d) => total += d,
                        Err(err) => {
                            result = Err(err);
                            break;
                        }
                    }
                }
                let _ = reply.send(result.map(|_| total));
            }
            Job::Stats { reply } => {
                let _ = reply.send((store.stats(), store.cached_len()));
            }
            Job::Shutdown => break,
        }
    }
    log_info!("engine", "worker {worker_id} down");
}
