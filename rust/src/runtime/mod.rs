//! Runtime layer: pluggable execution backends behind one engine.
//!
//! Pipeline: `artifact::Manifest` indexes the HLO artifacts emitted by
//! `python/compile/aot.py` (or synthesizes buckets for the native
//! backend); `backend::ExecBackend` is the execution contract, implemented
//! by `store::ExecutableStore` (PJRT, `pjrt` feature) and
//! `backend::NativeFlash` (pure-Rust tiled flash kernels);
//! `engine::Engine` runs one backend instance per dedicated worker thread
//! (PJRT handles are not `Send`).  `tensor::HostTensor` is the host-side
//! data currency.

pub mod artifact;
pub mod backend;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod store;
pub mod tensor;

pub use artifact::{ArtifactEntry, Manifest, TensorSpec};
pub use backend::{
    ApproxOffer, BackendKind, ExecBackend, ExecOutput, NativeFlash,
    PrepareCache, StoreStats,
};
pub use engine::Engine;
#[cfg(feature = "pjrt")]
pub use store::ExecutableStore;
pub use tensor::HostTensor;
