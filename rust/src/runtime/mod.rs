//! Runtime layer: load and execute AOT-compiled XLA artifacts via PJRT.
//!
//! Pipeline: `artifact::Manifest` indexes the HLO text files emitted by
//! `python/compile/aot.py`; `store::ExecutableStore` lazily compiles them on
//! a PJRT CPU client; `engine::Engine` runs stores on dedicated worker
//! threads so the (non-`Send`) PJRT handles never cross threads.
//! `tensor::HostTensor` is the host-side data currency.

pub mod artifact;
pub mod engine;
pub mod store;
pub mod tensor;

pub use artifact::{ArtifactEntry, Manifest, TensorSpec};
pub use engine::Engine;
pub use store::{ExecOutput, ExecutableStore, StoreStats};
pub use tensor::HostTensor;
