//! Experiment definitions: one function per paper table/figure.
//!
//! Each experiment returns a `Table` whose rows mirror the series the
//! paper reports (DESIGN.md §5 experiment index).  Absolute times here are
//! single-core CPU-PJRT numbers; the claims under reproduction are the
//! *orderings, scaling exponents and crossovers* — EXPERIMENTS.md places
//! them next to the paper's GPU numbers.
//!
//! The experiments drive `ExecutableStore` directly (single-threaded, no
//! queueing noise); the coordinator micro-bench exercises the L3 path.

use anyhow::{anyhow, Context, Result};

use crate::analysis::{flops, oracle_error, roofline::MachineModel};
use crate::data::mixture::{by_dim, Mixture};
use crate::estimator::flash::{self, TileConfig};
use crate::estimator::{bandwidth, native};
use crate::runtime::{ArtifactEntry, ExecutableStore, HostTensor, Manifest};
use crate::tuner::TuningTable;
use crate::util::rng::Pcg64;
use crate::util::stats;

use super::report::{fmt_err, fmt_ms, fmt_speedup, Table};
use super::runner::{black_box, measure, Measurement, RunSpec};

/// Shared experiment context.
pub struct Ctx {
    /// Compiled-executable store over the artifact manifest.
    pub store: ExecutableStore,
    /// Warmup/iteration policy shared by every experiment.
    pub spec: RunSpec,
    /// Override the default n-sweep (from `--sizes`).
    pub sizes_16d: Vec<usize>,
    /// Override the default 1-D n-sweep (from `--sizes`).
    pub sizes_1d: Vec<usize>,
    /// Run the slow native baseline up to this n (it is O(n² d) scalar).
    pub naive_max_n: usize,
    /// Independent data draws per oracle sweep.
    pub seeds: u64,
    /// Add the pure-Rust native flash backend as a third runtime series
    /// in the fig1/fig6 comparisons (`bench --native-series`; ROADMAP
    /// "native backend in the paper benches").
    pub native_series: bool,
    /// Tile-tuning table the native series consults per (d, n, m) for
    /// its block shapes (`bench --tuning`); `None` runs the static
    /// serial default.
    pub native_tuning: Option<TuningTable>,
}

impl Ctx {
    /// Open the artifact store and default sweep settings.
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Ctx> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Ctx {
            store: ExecutableStore::open(manifest)?,
            spec: RunSpec::default(),
            sizes_16d: vec![512, 1024, 2048, 4096, 8192],
            sizes_1d: vec![1024, 4096, 16384],
            naive_max_n: 2048,
            seeds: 3,
            native_series: false,
            native_tuning: None,
        })
    }

    /// The tile configuration the native series runs at one workload:
    /// the tuning table's nearest-bucket block shapes over a serial base
    /// (single-threaded like every other series here), or the static
    /// serial default without a table.
    fn native_tile(&self, d: usize, n: usize, m: usize) -> TileConfig {
        let base = TileConfig::serial();
        match &self.native_tuning {
            Some(t) => match t.lookup(d, n, m) {
                Some(cell) => cell.apply(base),
                None => base,
            },
            None => base,
        }
    }

    /// Keep only sweep sizes that actually have artifacts.
    fn present_sizes(&self, d: usize, pipeline: &str, variant: &str) -> Vec<usize> {
        let all = if d == 1 { &self.sizes_1d } else { &self.sizes_16d };
        all.iter()
            .copied()
            .filter(|&n| {
                self.store
                    .manifest()
                    .find(pipeline, variant, d, n, n / 8)
                    .is_some()
            })
            .collect()
    }
}

/// Benchmark problem data at one (n, m, d) from the canonical mixture.
pub struct Problem {
    /// [n, d] training points.
    pub x: HostTensor,
    /// [n] unit weights.
    pub w: HostTensor,
    /// [m, d] query points.
    pub y: HostTensor,
    /// SD-rate evaluation bandwidth for this draw.
    pub h: f64,
    /// Score bandwidth (`h / sqrt(2)`).
    pub h_score: f64,
    /// Analytic mixture density at the query points.
    pub truth_y: Vec<f64>,
    /// The generating mixture.
    pub mix: Mixture,
}

/// Draw one benchmark problem from the canonical mixture.
pub fn problem(n: usize, m: usize, d: usize, seed: u64) -> Problem {
    let mix = by_dim(d);
    let mut rng = Pcg64::new(seed, 77);
    let xs = mix.sample(n, &mut rng);
    let ys = mix.sample(m, &mut rng);
    let h = bandwidth::sdkde_rate(&xs, n, d);
    let h_score = bandwidth::score_bandwidth(h);
    let truth_y = mix.pdf(&ys);
    Problem {
        x: HostTensor::matrix(n, d, xs).expect("shape"),
        w: HostTensor::full(vec![n], 1.0),
        y: HostTensor::matrix(m, d, ys).expect("shape"),
        h,
        h_score,
        truth_y,
        mix,
    }
}

/// Build the input vector for a pipeline in wire order (see model.py).
pub fn inputs_for(pipeline: &str, p: &Problem) -> Vec<HostTensor> {
    let h = HostTensor::scalar(p.h as f32);
    let hs = HostTensor::scalar(p.h_score as f32);
    match pipeline {
        "kde" | "laplace" => vec![p.x.clone(), p.w.clone(), p.y.clone(), h],
        "sdkde_fit" => vec![p.x.clone(), p.w.clone(), h, hs],
        "sdkde_e2e" => vec![p.x.clone(), p.w.clone(), p.y.clone(), h, hs],
        other => panic!("unknown pipeline {other}"),
    }
}

/// Time one artifact end-to-end (inputs pre-built, outputs black-boxed).
fn time_artifact(
    ctx: &mut Ctx,
    entry: &ArtifactEntry,
    inputs: &[HostTensor],
    label: &str,
) -> Result<Measurement> {
    // Compile outside the timed region (serving steady-state behaviour).
    ctx.store.warm(entry)?;
    let spec = ctx.spec;
    let store = &mut ctx.store;
    let mut failure = None;
    let meas = measure(label, spec, || match store.execute(entry, inputs) {
        Ok(out) => {
            black_box(out.outputs);
        }
        Err(e) => failure = Some(e),
    });
    if let Some(e) = failure {
        return Err(e);
    }
    Ok(meas)
}

/// Run an artifact once and return the first output's data.
fn run_artifact(
    ctx: &mut Ctx,
    entry: &ArtifactEntry,
    inputs: &[HostTensor],
) -> Result<Vec<f32>> {
    let out = ctx.store.execute(entry, inputs)?;
    Ok(out
        .outputs
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("no output"))?
        .into_data())
}

fn find_entry(
    ctx: &Ctx,
    pipeline: &str,
    variant: &str,
    d: usize,
    n: usize,
    m: usize,
) -> Result<ArtifactEntry> {
    ctx.store
        .manifest()
        .find(pipeline, variant, d, n, m)
        .cloned()
        .with_context(|| format!("artifact {pipeline}/{variant} d={d} n={n} m={m} missing — rerun `make artifacts`"))
}

// ---------------------------------------------------------------------------
// Fig. 1 — 16-D runtime comparison (sklearn / Torch SD-KDE / Flash-SD-KDE).
// ---------------------------------------------------------------------------

/// Fig. 1: SD-KDE runtime vs n at d = 16, all variants.
pub fn fig1_runtime_16d(ctx: &mut Ctx) -> Result<Table> {
    runtime_comparison(ctx, 16, "fig1",
        "Fig.1 — 16-D SD-KDE runtime (ms), n_test = n/8")
}

/// Shared by Fig. 1 (d=16) and Fig. 6 (d=1).  With `Ctx::native_series`
/// the pure-Rust native flash backend rides along as a third measured
/// series (tile-tuned when `Ctx::native_tuning` is set), so the paper
/// figures show the artifact variants and the CPU backend side by side.
fn runtime_comparison(ctx: &mut Ctx, d: usize, id: &str, title: &str) -> Result<Table> {
    let sizes = ctx.present_sizes(d, "sdkde_e2e", "flash");
    let mut headers = vec!["n_train", "native naive", "SD-KDE (gemm)",
                           "Flash-SD-KDE", "speedup vs naive", "speedup vs gemm"];
    if ctx.native_series {
        headers.push("native flash (CPU)");
        headers.push("native vs gemm");
    }
    let mut table = Table::new(title, &headers);
    table.note("native naive = scalar-loop Rust (scikit-learn analogue); \
                gemm = materializing XLA baseline (Torch analogue)");
    if ctx.native_series {
        table.note(&format!(
            "native flash (CPU) = estimator::flash sdkde end-to-end, serial, {}",
            if ctx.native_tuning.is_some() {
                "block shapes from the tuning table (nearest bucket)"
            } else {
                "static default block shapes (tune + --tuning to apply a table)"
            }
        ));
    }
    for n in sizes {
        let m = n / 8;
        let p = problem(n, m, d, 42);

        // Native scalar baseline (capped: it is the slow one by design).
        let naive_ms = if n <= ctx.naive_max_n {
            let x = p.x.data().to_vec();
            let w = p.w.data().to_vec();
            let y = p.y.data().to_vec();
            let (h, hs) = (p.h, p.h_score);
            let meas = measure("naive", RunSpec::new(0, 1), || {
                black_box(native::sdkde(&x, &w, &y, d, h, hs));
            });
            Some(meas.mean_ms())
        } else {
            None
        };

        let gemm = find_entry(ctx, "sdkde_e2e", "gemm", d, n, m)?;
        let gemm_ms = time_artifact(ctx, &gemm, &inputs_for("sdkde_e2e", &p), "gemm")?
            .mean_ms();
        let flash = find_entry(ctx, "sdkde_e2e", "flash", d, n, m)?;
        let flash_ms =
            time_artifact(ctx, &flash, &inputs_for("sdkde_e2e", &p), "flash")?
                .mean_ms();

        // The native backend series: same problem, same spec, the tiled
        // CPU kernels compiled into this binary.
        let native_ms = if ctx.native_series {
            let cfg = ctx.native_tile(d, n, m);
            let x = p.x.data().to_vec();
            let w = p.w.data().to_vec();
            let y = p.y.data().to_vec();
            let (h, hs) = (p.h, p.h_score);
            let spec = ctx.spec;
            let meas = measure("native-flash", spec, || {
                black_box(flash::sdkde(&x, &w, &y, d, h, hs, &cfg));
            });
            Some(meas.mean_ms())
        } else {
            None
        };

        let mut row = vec![
            n.to_string(),
            naive_ms.map(fmt_ms).unwrap_or_else(|| "-".into()),
            fmt_ms(gemm_ms),
            fmt_ms(flash_ms),
            naive_ms
                .map(|nv| fmt_speedup(nv / flash_ms))
                .unwrap_or_else(|| "-".into()),
            fmt_speedup(gemm_ms / flash_ms),
        ];
        if let Some(nms) = native_ms {
            row.push(fmt_ms(nms));
            row.push(fmt_speedup(gemm_ms / nms));
        }
        table.row(row);
    }
    let mut t = table;
    t.notes.push(format!("iters={} warmup={}", ctx.spec.iters, ctx.spec.warmup));
    let _ = id;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 1 — comparison against the streaming (PyKeOps-analogue) baseline.
// ---------------------------------------------------------------------------

/// Table 1: PyKeOps-analogue (stream) comparison.
pub fn table1_keops(ctx: &mut Ctx) -> Result<Table> {
    let d = 16;
    // Paper: n=32k, m=4k; scaled to the largest artifact bucket present.
    let n = *ctx
        .present_sizes(d, "sdkde_e2e", "stream")
        .last()
        .ok_or_else(|| anyhow!("no stream artifacts"))?;
    let m = n / 8;
    let p = problem(n, m, d, 7);

    let mut rows: Vec<(String, f64)> = Vec::new();
    let flash = find_entry(ctx, "sdkde_e2e", "flash", d, n, m)?;
    let flash_ms =
        time_artifact(ctx, &flash, &inputs_for("sdkde_e2e", &p), "flash")?.mean_ms();
    rows.push(("16-D Flash-SD-KDE".into(), flash_ms));

    let kde_stream = find_entry(ctx, "kde", "stream", d, n, m)?;
    rows.push((
        "KeOps-style 16-D KDE (stream)".into(),
        time_artifact(ctx, &kde_stream, &inputs_for("kde", &p), "kde-stream")?
            .mean_ms(),
    ));
    let sd_stream = find_entry(ctx, "sdkde_e2e", "stream", d, n, m)?;
    rows.push((
        "KeOps-style 16-D SD-KDE (stream)".into(),
        time_artifact(ctx, &sd_stream, &inputs_for("sdkde_e2e", &p), "sd-stream")?
            .mean_ms(),
    ));

    let mut table = Table::new(
        &format!("Table 1 — vs streaming baseline @ n={n}, m={m}"),
        &["method", "runtime (ms)", "rel. to Flash-SD-KDE"],
    );
    table.note("paper: 2.11ms / 3.33ms (1.57x) / 16.91ms (7.99x) at n=32k on A6000");
    for (name, ms) in &rows {
        table.row(vec![name.clone(), fmt_ms(*ms), fmt_speedup(ms / flash_ms)]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Figs. 2/3 — oracle MISE/MIAE sweeps.
// ---------------------------------------------------------------------------

/// Fig. 2: oracle error vs n at d = 16.
pub fn fig2_oracle_16d(ctx: &mut Ctx) -> Result<Table> {
    oracle_sweep(ctx, 16, "Fig.2 — 16-D oracle error (MISE / MIAE)")
}

/// Fig. 3: oracle error vs n at d = 1.
pub fn fig3_oracle_1d(ctx: &mut Ctx) -> Result<Table> {
    oracle_sweep(ctx, 1, "Fig.3 — 1-D oracle error (MISE / MIAE)")
}

/// Oracle bandwidth grid per dimension.  Each estimator gets its *own*
/// oracle-tuned h (the paper's oracle-benchmark setting: the true density
/// is available, so each estimator is shown at its best) — bandwidth is a
/// runtime scalar input, so the whole grid reuses one compiled artifact.
fn h_grid(d: usize) -> Vec<f64> {
    let (lo, hi, steps) = if d == 1 { (0.04, 1.0, 10) } else { (0.4, 3.0, 8) };
    let ratio: f64 = (hi / lo as f64).powf(1.0 / (steps - 1) as f64);
    (0..steps).map(|i| lo * ratio.powi(i as i32)).collect()
}

/// Build pipeline inputs at an explicit bandwidth (h_score = h/sqrt(2)).
fn inputs_at_h(pipeline: &str, p: &Problem, h: f64) -> Vec<HostTensor> {
    let h_t = HostTensor::scalar(h as f32);
    let hs_t = HostTensor::scalar((h / std::f64::consts::SQRT_2) as f32);
    match pipeline {
        "kde" | "laplace" => vec![p.x.clone(), p.w.clone(), p.y.clone(), h_t],
        "sdkde_e2e" => vec![p.x.clone(), p.w.clone(), p.y.clone(), h_t, hs_t],
        other => panic!("unexpected pipeline {other}"),
    }
}

fn oracle_sweep(ctx: &mut Ctx, d: usize, title: &str) -> Result<Table> {
    let sizes = ctx.present_sizes(d, "sdkde_e2e", "flash");
    let estimators: [(&str, &str, &str); 4] = [
        ("KDE", "kde", "flash"),
        ("Flash-Laplace-KDE", "laplace", "flash"),
        ("Laplace (non-fused)", "laplace", "nonfused"),
        ("Flash-SD-KDE", "sdkde_e2e", "flash"),
    ];
    let mut table = Table::new(
        title,
        &["n_train", "estimator", "h*", "MISE", "MIAE", "neg.mass"],
    );
    table.note("signed-density errors, importance-sampled at n/8 mixture \
                draws; mean over seeds; h* oracle-tuned per estimator on \
                seed 0 (MISE-minimizing over a log grid)");
    for n in sizes {
        let m = n / 8;
        for (label, pipeline, variant) in estimators {
            let entry = find_entry(ctx, pipeline, variant, d, n, m)?;

            // Oracle bandwidth selection on the tuning seed.
            let tune = problem(n, m, d, 1000);
            let mut best = (f64::INFINITY, tune.h);
            for h in h_grid(d) {
                let dens = run_artifact(ctx, &entry, &inputs_at_h(pipeline, &tune, h))?;
                let est: Vec<f64> = dens.iter().map(|&v| v as f64).collect();
                let err = oracle_error(&est, &tune.truth_y);
                if err.mise < best.0 {
                    best = (err.mise, h);
                }
            }
            let h_star = best.1;

            // Measure over fresh seeds at the tuned bandwidth.
            let mut mises = Vec::new();
            let mut miaes = Vec::new();
            let mut negs = Vec::new();
            for seed in 0..ctx.seeds {
                let p = problem(n, m, d, 2000 + seed);
                let dens =
                    run_artifact(ctx, &entry, &inputs_at_h(pipeline, &p, h_star))?;
                let est: Vec<f64> = dens.iter().map(|&v| v as f64).collect();
                let err = oracle_error(&est, &p.truth_y);
                mises.push(err.mise);
                miaes.push(err.miae);
                negs.push(err.negative_mass);
            }
            table.row(vec![
                n.to_string(),
                label.to_string(),
                format!("{h_star:.3}"),
                fmt_err(stats::mean(&mises)),
                fmt_err(stats::mean(&miaes)),
                fmt_err(stats::mean(&negs)),
            ]);
        }
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Fig. 4 — fused vs non-fused Laplace runtime (1-D) + speedups.
// ---------------------------------------------------------------------------

/// Fig. 4: fused vs non-fused Laplace ablation at d = 1.
pub fn fig4_fusion_1d(ctx: &mut Ctx) -> Result<Table> {
    let d = 1;
    let sizes = ctx.present_sizes(d, "laplace", "flash");
    let mut table = Table::new(
        "Fig.4 — Laplace fusion runtime (1-D)",
        &["n_train", "fused (ms)", "non-fused (ms)", "fusion speedup",
          "SD-KDE/Laplace ratio"],
    );
    for n in sizes {
        let m = n / 8;
        let p = problem(n, m, d, 11);
        let fused = find_entry(ctx, "laplace", "flash", d, n, m)?;
        let fused_ms =
            time_artifact(ctx, &fused, &inputs_for("laplace", &p), "fused")?.mean_ms();
        let nonfused = find_entry(ctx, "laplace", "nonfused", d, n, m)?;
        let nonfused_ms =
            time_artifact(ctx, &nonfused, &inputs_for("laplace", &p), "nonfused")?
                .mean_ms();
        let sdkde = find_entry(ctx, "sdkde_e2e", "flash", d, n, m)?;
        let sdkde_ms =
            time_artifact(ctx, &sdkde, &inputs_for("sdkde_e2e", &p), "sdkde")?
                .mean_ms();
        table.row(vec![
            n.to_string(),
            fmt_ms(fused_ms),
            fmt_ms(nonfused_ms),
            fmt_speedup(nonfused_ms / fused_ms),
            fmt_speedup(sdkde_ms / fused_ms),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Figs. 5/7 — utilization from the flop model + measured runtimes.
// ---------------------------------------------------------------------------

/// Fig. 5: matrix-unit utilization vs n at d = 16.
pub fn fig5_utilization_16d(ctx: &mut Ctx) -> Result<Table> {
    utilization_sweep(ctx, 16, "Fig.5 — 16-D utilization (flop model / measured)")
}

/// Fig. 7: matrix-unit utilization vs n at d = 1.
pub fn fig7_utilization_1d(ctx: &mut Ctx) -> Result<Table> {
    utilization_sweep(ctx, 1, "Fig.7 — 1-D utilization, flash vs gemm")
}

fn utilization_sweep(ctx: &mut Ctx, d: usize, title: &str) -> Result<Table> {
    let machine = MachineModel::cpu_testbed();
    let sizes = ctx.present_sizes(d, "sdkde_e2e", "flash");
    let mut table = Table::new(
        title,
        &["n_train", "variant", "runtime (ms)", "model GFLOPs",
          "util (testbed)", "util (A6000-scale)"],
    );
    table.note(&format!(
        "testbed peak {:.0e} FLOP/s; A6000-scale column = what the same \
         FLOPs/runtime ratio would mean against the paper's 155 TFLOP/s peak \
         (context only)",
        machine.matrix_peak
    ));
    for n in sizes {
        let m = n / 8;
        let p = problem(n, m, d, 23);
        let model_flops = if d == 1 {
            flops::sdkde_flops_1d(n as f64, Some(m as f64))
        } else {
            flops::sdkde_flops_d(n as f64, d, Some(m as f64))
        };
        for variant in ["flash", "gemm"] {
            let entry = find_entry(ctx, "sdkde_e2e", variant, d, n, m)?;
            let ms = time_artifact(ctx, &entry, &inputs_for("sdkde_e2e", &p), variant)?
                .mean_ms();
            let s = ms / 1e3;
            table.row(vec![
                n.to_string(),
                variant.to_string(),
                fmt_ms(ms),
                format!("{:.2}", model_flops / 1e9),
                format!("{:.2}%", 100.0 * flops::utilization(model_flops, s, machine.matrix_peak)),
                format!("{:.4}%", 100.0 * flops::utilization(model_flops, s, flops::A6000_TC_PEAK_FLOPS)),
            ]);
        }
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Fig. 6 — 1-D runtime comparison (appendix sweep).
// ---------------------------------------------------------------------------

/// Fig. 6: runtime vs n at d = 1, all variants.
pub fn fig6_runtime_1d(ctx: &mut Ctx) -> Result<Table> {
    runtime_comparison(ctx, 1, "fig6",
        "Fig.6 — 1-D SD-KDE runtime (ms), n_test = n/8")
}

// ---------------------------------------------------------------------------
// §6.2 — launch-parameter (BLOCK_M x BLOCK_N) sweep ablation.
// ---------------------------------------------------------------------------

/// §6.2: BLOCK_M x BLOCK_N launch-parameter sweep.
pub fn ablation_blocksweep(ctx: &mut Ctx) -> Result<Table> {
    let entries: Vec<ArtifactEntry> = ctx
        .store
        .manifest()
        .sweep_entries()
        .into_iter()
        .cloned()
        .collect();
    if entries.is_empty() {
        return Err(anyhow!("no sweep artifacts (build without --quick/--no-sweep)"));
    }
    let mut table = Table::new(
        "§6.2 — BlockSpec tile sweep (sdkde_fit, d=16)",
        &["BLOCK_M", "BLOCK_N", "runtime (ms)", "VMEM est (KiB)", "vs best"],
    );
    table.note("paper swept BLOCK_M/BLOCK_N/num_warps/num_stages on Triton; \
                here the BlockSpec pair is the TPU analogue (DESIGN.md §2)");
    let mut results = Vec::new();
    for entry in &entries {
        let p = problem(entry.n, entry.m, entry.d, 5);
        let ms = time_artifact(ctx, entry, &inputs_for("sdkde_fit", &p), "sweep")?
            .mean_ms();
        let (bm, bn) = entry.tiles.expect("sweep entries carry tiles");
        // VMEM estimate mirrors python common.TileConfig.vmem_bytes.
        let vmem = 4 * (bm * entry.d + bn * entry.d + bn + bm * (entry.d + 1));
        results.push((bm, bn, ms, vmem));
    }
    let best = results
        .iter()
        .map(|r| r.2)
        .fold(f64::INFINITY, f64::min);
    results.sort_by(|a, b| a.2.partial_cmp(&b.2).expect("no NaN"));
    for (bm, bn, ms, vmem) in results {
        table.row(vec![
            bm.to_string(),
            bn.to_string(),
            fmt_ms(ms),
            format!("{:.1}", vmem as f64 / 1024.0),
            fmt_speedup(ms / best),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Headline scale: biggest run + power-law extrapolation to the paper's 1M.
// ---------------------------------------------------------------------------

/// Headline large-scale runs (abstract's end-to-end claim).
pub fn headline_scale(ctx: &mut Ctx) -> Result<Table> {
    let d = 16;
    let sizes = ctx.present_sizes(d, "sdkde_e2e", "flash");
    let mut ns = Vec::new();
    let mut times = Vec::new();
    let mut table = Table::new(
        "Headline — Flash-SD-KDE scaling and 1M-point extrapolation",
        &["n_train", "n_test", "runtime (ms)"],
    );
    for &n in &sizes {
        let m = n / 8;
        let p = problem(n, m, d, 3);
        let entry = find_entry(ctx, "sdkde_e2e", "flash", d, n, m)?;
        let ms = time_artifact(ctx, &entry, &inputs_for("sdkde_e2e", &p), "flash")?
            .mean_ms();
        ns.push(n as f64);
        times.push(ms);
        table.row(vec![n.to_string(), m.to_string(), fmt_ms(ms)]);
    }
    if ns.len() >= 2 {
        let (c, pexp) = stats::power_law_fit(&ns, &times);
        let n1m: f64 = 1_048_576.0;
        let extrapolated_ms = c * n1m.powf(pexp);
        table.note(&format!(
            "power-law fit: t(n) = {c:.3e} * n^{pexp:.3} ms (expected exponent ~2)"
        ));
        table.note(&format!(
            "extrapolated 1M-train/131k-query runtime on this CPU testbed: {:.1} s \
             (paper: 2.3 s on an A6000)",
            extrapolated_ms / 1e3
        ));
    }
    Ok(table)
}
