//! Exact-vs-approx frontier — sweeps the native backend's error budgets
//! (DESIGN.md §14) against the exact serving hot path, with **zero
//! artifacts and zero XLA**: compiled into every build, like
//! [`native_cmp`](super::native_cmp).
//!
//! For each train size on the paper's 16-d mixture the sweep measures the
//! exact baseline (`flash::kde_prepared` over a resident
//! [`PreparedTrain`], the simd+cached series) and then, for each budget
//! `rel_err ∈ {0.5, 0.1, 0.02}`, the approximate per-query path exactly
//! as `NativeFlash::execute_approx` serves it: the RFF sketch answers
//! when its noise floor accepts, the DEANN index otherwise.  Each row
//! reports the speedup AND the measured relative error against the exact
//! values, so the frontier (how much error buys how much speed) is
//! visible per point — the BENCHMARKS.md "Exact vs approx frontier"
//! record tracks the `rel_err = 0.1` row across PRs.

use anyhow::Result;

use crate::approx::{deann::DeannIndex, default_seed, rff::RffSketch};
use crate::data::mixture::by_dim;
use crate::estimator::bandwidth;
use crate::estimator::flash::{self, PreparedTrain, TileConfig};
use crate::util::rng::Pcg64;

use super::report::{fmt_ms, fmt_speedup, Table};
use super::runner::{black_box, measure, RunSpec};

/// Default n sweep — the largest point is the acceptance workload
/// (n = 256k, 16-d, where `rel_err = 0.1` must clear 5× over exact).
pub const DEFAULT_SIZES: &[usize] = &[32_768, 131_072, 262_144];

/// CI-smoke sweep (`bench --experiment frontier --quick`).
pub const QUICK_SIZES: &[usize] = &[2_048];

/// Error budgets swept per train size, loosest first.
pub const REL_ERRS: &[f64] = &[0.5, 0.1, 0.02];

/// Queries are capped so the exact O(n·m·d) baseline stays measurable at
/// the largest n; the cap still gives a dense error sample per cell.
const MAX_QUERIES: usize = 4_096;

/// Sweep the exact-vs-approx frontier on the 16-d mixture: one row per
/// (n, rel_err) with the exact and approx runtimes, the speedup, the
/// measured max relative error, and how many queries the RFF sketch
/// served (the rest fell to the DEANN index).  Index/sketch build happens
/// at prepare time in the serving path and is excluded from the timings
/// (it is amortized across a resident model's queries), but is reported
/// in a note.
pub fn exact_vs_approx(spec: RunSpec, sizes: &[usize]) -> Result<Table> {
    let d = 16;
    let mix = by_dim(d);
    let mut table = Table::new(
        "Exact vs approx frontier — KDE eval runtime (ms), d=16, 1 thread",
        &["n_train", "rel_err", "exact", "approx", "speedup", "max rel err",
          "rff share"],
    );
    table.note(
        "approx = the native backend's per-query path (DESIGN.md §14): the \
         RFF sketch answers when its noise floor accepts the budget, the \
         DEANN index otherwise; index/sketch build is prepare-time state \
         (amortized across a resident model's queries) and excluded here",
    );
    table.note(
        "max rel err = max_i |approx_i − exact_i| / max(|exact_i|, 1e-30) \
         against the exact native kernel's served values",
    );
    let simd_cfg = TileConfig { simd: true, ..TileConfig::serial() };
    let seed = default_seed("frontier");
    for &n in sizes {
        let m = (n / 8).clamp(1, MAX_QUERIES);
        let mut rng = Pcg64::new(42, 77);
        let x = mix.sample(n, &mut rng);
        let y = mix.sample(m, &mut rng);
        let w = vec![1.0f32; n];
        let h = bandwidth::sdkde_rate(&x, n, d);

        let train = PreparedTrain::new(&x, &w, d);
        let exact_vals = flash::kde_prepared(&train, &y, h, &simd_cfg);
        let exact_ms = measure("exact", spec, || {
            black_box(flash::kde_prepared(&train, &y, h, &simd_cfg));
        })
        .mean_ms();

        let build = std::time::Instant::now();
        let deann = DeannIndex::build(&x, &w, d);
        let deann_build_ms = build.elapsed().as_secs_f64() * 1e3;
        table.note(&format!(
            "n={n}: m={m}, DEANN index {} cells built in {} ({} KiB)",
            deann.cells(),
            fmt_ms(deann_build_ms),
            deann.bytes() / 1024
        ));
        for &rel_err in REL_ERRS {
            let sketch = RffSketch::build(&x, &w, d, h, rel_err);
            // One untimed pass collects the served values (for the error
            // column) and which estimator answered each query.
            let mut vals = Vec::with_capacity(m);
            let mut rff_served = 0usize;
            for (i, q) in y.chunks_exact(d).enumerate() {
                let v = match sketch
                    .as_ref()
                    .and_then(|sk| sk.density(q, h, rel_err))
                {
                    Some(v) => {
                        rff_served += 1;
                        v
                    }
                    None => deann.density(q, h, rel_err, seed, i as u64),
                };
                vals.push(v);
            }
            let approx_ms = measure("approx", spec, || {
                for (i, q) in y.chunks_exact(d).enumerate() {
                    let v = sketch
                        .as_ref()
                        .and_then(|sk| sk.density(q, h, rel_err))
                        .unwrap_or_else(|| {
                            deann.density(q, h, rel_err, seed, i as u64)
                        });
                    black_box(v);
                }
            })
            .mean_ms();
            let max_err = vals
                .iter()
                .zip(&exact_vals)
                .map(|(&a, &e)| (a - e).abs() / e.abs().max(1e-30))
                .fold(0.0f64, f64::max);
            table.row(vec![
                n.to_string(),
                format!("{rel_err}"),
                fmt_ms(exact_ms),
                fmt_ms(approx_ms),
                fmt_speedup(exact_ms / approx_ms),
                format!("{max_err:.4}"),
                format!("{rff_served}/{m}"),
            ]);
        }
    }
    table.notes.push(format!(
        "iters={} warmup={} (queries capped at {MAX_QUERIES})",
        spec.iters, spec.warmup
    ));
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_runs_and_stays_within_budget() {
        let t = exact_vs_approx(RunSpec::new(0, 1), QUICK_SIZES).unwrap();
        // One row per (n, rel_err).
        assert_eq!(t.rows.len(), QUICK_SIZES.len() * REL_ERRS.len());
        assert_eq!(t.headers.len(), 7);
        for row in &t.rows {
            let budget: f64 = row[1].parse().unwrap();
            let measured: f64 = row[5].parse().unwrap();
            // DEANN's deterministic stopping rule holds per query; the
            // exact oracle here is the f32-input flash kernel, so allow
            // its own rounding on top of the budget.
            assert!(
                measured <= budget + 5e-3,
                "budget {budget} exceeded: {row:?}"
            );
            assert!(row[4].ends_with('x'), "{row:?}");
        }
    }
}
