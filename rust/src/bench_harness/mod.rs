//! Bench harness: measurement runner, table reporter and the experiment
//! suite regenerating every table/figure in the paper (DESIGN.md §5).
//!
//! `cargo bench` targets under `rust/benches/` are thin wrappers over
//! `experiments::*`; the `flash-sdkde bench --experiment <id>` CLI reaches
//! the same functions.  The artifact-driven experiments need the `pjrt`
//! feature; the `native` comparison (`native_cmp`) runs in any build with
//! zero artifacts.

#[cfg(feature = "pjrt")]
pub mod experiments;
pub mod frontier;
pub mod linalg;
pub mod native_cmp;
pub mod report;
pub mod runner;

pub use report::Table;
pub use runner::{black_box, measure, Measurement, RunSpec};

#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Artifact-driven experiment ids addressable from the CLI and bench
/// targets (the `native` comparison is dispatched separately — it needs
/// neither artifacts nor the `pjrt` feature).
pub const EXPERIMENTS: &[&str] = &[
    "fig1", "table1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7",
    "blocksweep", "headline",
];

/// Dispatch one artifact-driven experiment by id.
#[cfg(feature = "pjrt")]
pub fn run_experiment(ctx: &mut experiments::Ctx, id: &str) -> Result<Table> {
    match id {
        "fig1" => experiments::fig1_runtime_16d(ctx),
        "table1" => experiments::table1_keops(ctx),
        "fig2" => experiments::fig2_oracle_16d(ctx),
        "fig3" => experiments::fig3_oracle_1d(ctx),
        "fig4" => experiments::fig4_fusion_1d(ctx),
        "fig5" => experiments::fig5_utilization_16d(ctx),
        "fig6" => experiments::fig6_runtime_1d(ctx),
        "fig7" => experiments::fig7_utilization_1d(ctx),
        "blocksweep" => experiments::ablation_blocksweep(ctx),
        "headline" => experiments::headline_scale(ctx),
        other => Err(anyhow::anyhow!(
            "unknown experiment {other:?}; available: {EXPERIMENTS:?} + \"native\""
        )),
    }
}
