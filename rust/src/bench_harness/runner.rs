//! Measurement core (criterion is unavailable offline; this provides the
//! subset the experiment suite needs: warmup, repeated timed runs, and
//! robust summaries).

use std::time::Instant;

use crate::util::stats::Summary;

/// Benchmark knobs.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec {
    /// Unmeasured warmup iterations.
    pub warmup: usize,
    /// Measured iterations.
    pub iters: usize,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec { warmup: 1, iters: 3 }
    }
}

impl RunSpec {
    /// Knobs with at least one measured iteration.
    pub fn new(warmup: usize, iters: usize) -> Self {
        assert!(iters >= 1, "need at least one measured iteration");
        RunSpec { warmup, iters }
    }
}

/// One benchmark measurement in seconds.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label the measurement ran under.
    pub name: String,
    /// Wall-time summary over the measured iterations, seconds.
    pub seconds: Summary,
}

impl Measurement {
    /// Mean wall time, milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.seconds.mean * 1e3
    }

    /// Fastest iteration, milliseconds.
    pub fn min_ms(&self) -> f64 {
        self.seconds.min * 1e3
    }
}

/// Run `f` with warmup and return a summary of wall times.
///
/// `f` must perform the complete operation each call (the runner adds no
/// per-iteration sync; XLA executions are synchronous already).
pub fn measure<F: FnMut()>(name: &str, spec: RunSpec, mut f: F) -> Measurement {
    for _ in 0..spec.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(spec.iters);
    for _ in 0..spec.iters {
        let start = Instant::now();
        f();
        samples.push(start.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), seconds: Summary::of(&samples) }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_warmup_plus_iters() {
        let mut calls = 0usize;
        let m = measure("t", RunSpec::new(2, 5), || {
            calls += 1;
        });
        assert_eq!(calls, 7);
        assert_eq!(m.seconds.count, 5);
        assert_eq!(m.name, "t");
    }

    #[test]
    fn measure_times_are_sane() {
        let m = measure("sleep", RunSpec::new(0, 2), || {
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        assert!(m.mean_ms() >= 5.0, "mean={}", m.mean_ms());
        assert!(m.mean_ms() < 500.0);
        assert!(m.min_ms() <= m.mean_ms());
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_iters_rejected() {
        RunSpec::new(1, 0);
    }
}
