//! Kernel-ops benchmark — the linear-algebra pipeline family (MatVec,
//! kernel PCA, MMD; DESIGN.md §17) on the native flash tiles, with
//! **zero artifacts and zero XLA**: compiled into every build, like
//! [`native_cmp`](super::native_cmp) and [`frontier`](super::frontier).
//!
//! Per train size on the paper's 16-d mixture the sweep measures:
//!
//! * `matvec` — one weighted `K·v` pass over `m = n/8` query rows,
//!   against the same-shape `kde` pass.  Both ride the identical
//!   `kernel_sum` tiles, so the `mv/kde` ratio should hover at ~1× —
//!   drift is a regression in the effective-weights factoring.
//! * `pca` — a fixed [`PCA_SWEEPS`]-sweep power iteration on the
//!   centered kernel matrix (`tol` pinned far below f32 resolution so
//!   every run does identical work: each sweep is one n-row MatVec).
//! * `mmd` — the two-sample statistic against an equal-size fresh draw
//!   (three kernel sums, n² + n·m + m² pairs).
//!
//! BENCHMARKS.md §"Kernel ops" tracks the largest-n row across PRs.

use anyhow::Result;

use crate::data::mixture::by_dim;
use crate::estimator::bandwidth;
use crate::estimator::flash::{self, PreparedTrain, TileConfig};
use crate::linalg::{kernel_pca, mmd, PcaOpts};
use crate::util::rng::Pcg64;

use super::report::{fmt_ms, Table};
use super::runner::{black_box, measure, RunSpec};

/// Default n sweep.  PCA and MMD are O(n²d) *per sweep*, so the ceiling
/// sits well below the density benches' (which pay n·m with m capped).
pub const DEFAULT_SIZES: &[usize] = &[4_096, 16_384];

/// CI-smoke sweep (`bench --experiment linalg --quick`).
pub const QUICK_SIZES: &[usize] = &[1_024];

/// Power-iteration sweeps measured per size — fixed (tolerance pinned
/// unreachably low) so every run times identical work.
pub const PCA_SWEEPS: usize = 8;

/// Sweep the kernel-ops runtimes on the 16-d mixture: one row per train
/// size.
pub fn kernel_ops(spec: RunSpec, sizes: &[usize]) -> Result<Table> {
    let d = 16;
    let mix = by_dim(d);
    let mut table = Table::new(
        "Kernel ops — MatVec / kernel PCA / MMD runtime (ms), d=16, \
         default threads",
        &["n_train", "m", "matvec", "kde", "mv/kde", "pca", "mmd"],
    );
    table.note(
        "matvec and kde share the kernel_sum tiles over the same [m, d] \
         query block — their ratio is the factoring overhead (expect ~1x)",
    );
    table.note(&format!(
        "pca = {PCA_SWEEPS} power-iteration sweeps (tol pinned below f32 \
         resolution; each sweep is one n-row MatVec); mmd = biased \
         V-statistic vs an equal-size fresh draw"
    ));
    let cfg = TileConfig::default();
    for &n in sizes {
        let m = (n / 8).max(1);
        let mut rng = Pcg64::new(42, 88);
        let x = mix.sample(n, &mut rng);
        let y = mix.sample(m, &mut rng);
        let x2 = mix.sample(n, &mut rng);
        let w = vec![1.0f32; n];
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let h = bandwidth::sdkde_rate(&x, n, d);
        let train = PreparedTrain::new(&x, &w, d);

        let matvec_ms = measure("matvec", spec, || {
            black_box(flash::matvec_prepared(&train, &v, &y, h, &cfg));
        })
        .mean_ms();
        let kde_ms = measure("kde", spec, || {
            black_box(flash::kde_prepared(&train, &y, h, &cfg));
        })
        .mean_ms();
        let pca_opts = PcaOpts { max_iters: PCA_SWEEPS, tol: 1e-300, ..PcaOpts::default() };
        let pca_ms = measure("pca", spec, || {
            black_box(kernel_pca(&x, &w, d, h, &cfg, &pca_opts).unwrap());
        })
        .mean_ms();
        let mmd_ms = measure("mmd", spec, || {
            black_box(mmd(&x, &x2, d, h, &cfg).unwrap());
        })
        .mean_ms();

        table.row(vec![
            n.to_string(),
            m.to_string(),
            fmt_ms(matvec_ms),
            fmt_ms(kde_ms),
            format!("{:.2}x", matvec_ms / kde_ms),
            fmt_ms(pca_ms),
            fmt_ms(mmd_ms),
        ]);
    }
    table.notes.push(format!("iters={} warmup={}", spec.iters, spec.warmup));
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_ops_quick_sweep_runs() {
        let t = kernel_ops(RunSpec::new(0, 1), QUICK_SIZES).unwrap();
        assert_eq!(t.rows.len(), QUICK_SIZES.len());
        assert_eq!(t.headers.len(), 7);
        for row in &t.rows {
            assert!(row[4].ends_with('x'), "{row:?}");
        }
    }
}
