//! Native-flash vs scalar-baseline comparison — the CPU analogue of the
//! paper's Fig. 1 that needs **zero artifacts and zero XLA**: every series
//! is compiled into this binary.
//!
//! Four series over the paper's 16-d workload (n_test = n/8), all
//! single-threaded so the kernel wins are not conflated with threading:
//!
//! 1. **scalar** — `estimator::native::kde`, the deliberately-scalar
//!    scikit-learn analogue (pairwise ‖x−y‖² recomputed per coordinate).
//! 2. **tile (auto-vec)** — the PR 2 flash kernel: matmul identity with
//!    compiler-vectorized f32 dot tiles ([`TileConfig::scalar_tiles`]),
//!    re-deriving the prepared train state every call (what the backend
//!    did before the prepare cache).
//! 3. **simd** — the same kernel with explicit `std::simd` lanes
//!    (`TileConfig { simd: true }`; identical to series 2 in builds
//!    without the `simd` cargo feature — the table notes say which ran).
//! 4. **simd+cached** — series 3 over a [`flash::PreparedTrain`] built
//!    once and reused, i.e. the serving hot path for a resident model
//!    (DESIGN.md §11).
//!
//! With a tuning table ([`crate::tuner`]) a fifth series, **tuned**,
//! runs series 4 under the table's nearest-bucket block shapes — the
//! tuned-vs-default record BENCHMARKS.md tracks.
//!
//! The workload is the query ("decode") side — a KDE eval sweep — since
//! that is what the prepare cache amortizes; BENCHMARKS.md records the
//! series across PRs.

use anyhow::Result;

use crate::data::mixture::by_dim;
use crate::estimator::flash::{self, PreparedTrain, TileConfig};
use crate::estimator::{bandwidth, native};
use crate::tuner::TuningTable;
use crate::util::rng::Pcg64;

use super::report::{fmt_ms, fmt_speedup, Table};
use super::runner::{black_box, measure, RunSpec};

/// Default n sweep for the 16-d comparison.
pub const DEFAULT_SIZES: &[usize] = &[1024, 2048, 4096, 8192];

/// Default cap for the O(n·m·d) scalar baseline — shared by the CLI and
/// the `native_flash` bench target so the entry points cannot diverge.
pub const DEFAULT_NAIVE_MAX_N: usize = 8192;

/// Default number of independent data draws.
pub const DEFAULT_SEEDS: u64 = 1;

/// KDE eval runtime over the four native series — plus a fifth, `tuned`,
/// when a tuning table is given: the `simd+cached` hot path under the
/// table's nearest-bucket block shapes instead of the static default
/// (the BENCHMARKS.md "tuned vs default" record — run with and without
/// `--tuning` to produce both sides).  Times are means over `seeds`
/// independent data draws (x measurement iterations each, per `spec`).
pub fn native_vs_scalar(
    spec: RunSpec,
    sizes: &[usize],
    naive_max_n: usize,
    seeds: u64,
    tuning: Option<&TuningTable>,
) -> Result<Table> {
    let seeds = seeds.max(1);
    let d = 16;
    let mix = by_dim(d);
    let mut headers = vec!["n_train", "scalar", "tile (auto-vec)", "simd",
                           "simd+cached", "simd vs tile", "cached vs tile"];
    if tuning.is_some() {
        headers.push("tuned");
        headers.push("tuned vs cached");
    }
    let mut table = Table::new(
        "Native backend — KDE eval runtime (ms), d=16, n_test = n/8, 1 thread",
        &headers,
    );
    table.note(
        "scalar = estimator::native (pairwise ‖x−y‖² recomputed per \
         coordinate, f64); tile = matmul identity ‖x−y‖² = ‖x‖²+‖y‖²−2x·yᵀ \
         with f32 dot tiles + f64 accumulators (estimator::flash), train \
         state re-derived per call; cached = PreparedTrain built once \
         (the resident-model serving hot path)",
    );
    table.note(if cfg!(feature = "simd") {
        "simd = explicit std::simd lanes (f32x8 dot tile, f64x4 \
         exp/accumulate; `simd` feature on)"
    } else {
        "simd = built WITHOUT the `simd` feature: series runs the \
         auto-vectorized tile (rebuild with nightly + --features simd)"
    });
    if let Some(t) = tuning {
        table.note(&format!(
            "tuned = simd+cached under the tuning table's nearest-bucket \
             block shapes ({} cells; run without --tuning for the default \
             side of the BENCHMARKS.md tuned-vs-default record)",
            t.cells().len()
        ));
    }
    let tile_cfg = TileConfig::scalar_tiles();
    let simd_cfg = TileConfig { simd: true, ..TileConfig::serial() };
    for &n in sizes {
        let m = (n / 8).max(1);
        let tuned_cell = tuning.and_then(|t| t.lookup(d, n, m));
        let tuned_cfg = tuning.map(|_| {
            tuned_cell.map(|c| c.apply(simd_cfg)).unwrap_or(simd_cfg)
        });
        if tuning.is_some() && tuned_cell.is_none() {
            table.note(&format!(
                "n={n}: table has no d={d} cell — tuned series ran the \
                 static config (tune --dims {d} to cover it)"
            ));
        }
        let mut sums = [0.0f64; 5]; // scalar, tile, simd, cached, tuned
        for seed in 0..seeds {
            let mut rng = Pcg64::new(42 + seed, 77);
            let x = mix.sample(n, &mut rng);
            let y = mix.sample(m, &mut rng);
            let w = vec![1.0f32; n];
            let h = bandwidth::sdkde_rate(&x, n, d);

            if n <= naive_max_n {
                sums[0] += measure("scalar", spec, || {
                    black_box(native::kde(&x, &w, &y, d, h));
                })
                .mean_ms();
            }
            sums[1] += measure("tile", spec, || {
                black_box(flash::kde(&x, &w, &y, d, h, &tile_cfg));
            })
            .mean_ms();
            sums[2] += measure("simd", spec, || {
                black_box(flash::kde(&x, &w, &y, d, h, &simd_cfg));
            })
            .mean_ms();
            let train = PreparedTrain::new(&x, &w, d);
            sums[3] += measure("simd-cached", spec, || {
                black_box(flash::kde_prepared(&train, &y, h, &simd_cfg));
            })
            .mean_ms();
            if let Some(cfg) = &tuned_cfg {
                sums[4] += measure("tuned", spec, || {
                    black_box(flash::kde_prepared(&train, &y, h, cfg));
                })
                .mean_ms();
            }
        }
        let scalar_ms =
            (n <= naive_max_n).then_some(sums[0] / seeds as f64);
        let tile_ms = sums[1] / seeds as f64;
        let simd_ms = sums[2] / seeds as f64;
        let cached_ms = sums[3] / seeds as f64;

        let mut row = vec![
            n.to_string(),
            scalar_ms.map(fmt_ms).unwrap_or_else(|| "-".into()),
            fmt_ms(tile_ms),
            fmt_ms(simd_ms),
            fmt_ms(cached_ms),
            fmt_speedup(tile_ms / simd_ms),
            fmt_speedup(tile_ms / cached_ms),
        ];
        if tuned_cfg.is_some() {
            let tuned_ms = sums[4] / seeds as f64;
            row.push(fmt_ms(tuned_ms));
            row.push(fmt_speedup(cached_ms / tuned_ms));
        }
        table.row(row);
    }
    table
        .notes
        .push(format!("iters={} warmup={} seeds={seeds}", spec.iters, spec.warmup));
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_runs_without_artifacts() {
        let t = native_vs_scalar(RunSpec::new(0, 1), &[128], 256, 2, None).unwrap();
        assert_eq!(t.rows.len(), 1);
        // No tuning table: the base seven columns only.
        assert_eq!(t.headers.len(), 7);
        // Scalar column populated (128 <= cap) and speedups parse as "x".
        assert_ne!(t.rows[0][1], "-");
        assert!(t.rows[0][5].ends_with('x'), "{:?}", t.rows[0]);
        assert!(t.rows[0][6].ends_with('x'), "{:?}", t.rows[0]);
    }

    #[test]
    fn scalar_cap_blanks_the_baseline_column() {
        let t = native_vs_scalar(RunSpec::new(0, 1), &[128], 64, 1, None).unwrap();
        assert_eq!(t.rows[0][1], "-");
        // Flash series still measured.
        assert_ne!(t.rows[0][2], "-");
    }

    #[test]
    fn tuning_table_adds_the_tuned_series() {
        use crate::tuner::{TunedCell, TuningTable};
        let table = TuningTable::new(vec![TunedCell {
            d: 16,
            n: 128,
            m: 16,
            block_q: 16,
            block_t: 64,
            threads: 1,
            simd: false,
            best_ms: 0.1,
            default_ms: 0.2,
        }])
        .unwrap();
        let t = native_vs_scalar(RunSpec::new(0, 1), &[128], 64, 1, Some(&table))
            .unwrap();
        assert_eq!(t.headers.len(), 9);
        assert_eq!(t.headers[7], "tuned");
        assert_ne!(t.rows[0][7], "-");
        assert!(t.rows[0][8].ends_with('x'), "{:?}", t.rows[0]);
    }
}
