//! Native-flash vs scalar-baseline comparison — the CPU analogue of the
//! paper's Fig. 1 that needs **zero artifacts and zero XLA**: both sides
//! are compiled into this binary.
//!
//! The scalar baseline is `estimator::native` (the deliberately-scalar
//! scikit-learn analogue); the contender is `estimator::flash` (the
//! matmul-identity reordering with f32 dot tiles, f64 accumulators and
//! threaded query blocks).  Reported at the paper's 16-d workload with
//! n_test = n/8, both single-threaded (the pure reordering win) and at
//! the default thread count (the serving configuration).

use anyhow::Result;

use crate::data::mixture::by_dim;
use crate::estimator::flash::{self, TileConfig};
use crate::estimator::{bandwidth, native};
use crate::util::rng::Pcg64;

use super::report::{fmt_ms, fmt_speedup, Table};
use super::runner::{black_box, measure, RunSpec};

/// Default n sweep for the 16-d comparison.
pub const DEFAULT_SIZES: &[usize] = &[1024, 2048, 4096, 8192];

/// Default cap for the O(n²d) scalar baseline — shared by the CLI and the
/// `native_flash` bench target so the entry points cannot diverge.
pub const DEFAULT_NAIVE_MAX_N: usize = 8192;

/// Default number of independent data draws.
pub const DEFAULT_SEEDS: u64 = 1;

/// Full SD-KDE (debias + evaluate) runtime: scalar oracle vs native-flash.
/// Times are means over `seeds` independent data draws (x measurement
/// iterations each, per `spec`).
pub fn native_vs_scalar(
    spec: RunSpec,
    sizes: &[usize],
    naive_max_n: usize,
    seeds: u64,
) -> Result<Table> {
    let seeds = seeds.max(1);
    let d = 16;
    let mix = by_dim(d);
    let mut table = Table::new(
        "Native backend — SD-KDE runtime (ms), d=16, n_test = n/8",
        &["n_train", "scalar baseline", "flash (1 thread)",
          "flash (threaded)", "speedup (1t)", "speedup"],
    );
    table.note(
        "scalar = estimator::native (pairwise ‖x−y‖² recomputed per \
         coordinate, f64); flash = matmul identity ‖x−y‖² = ‖x‖²+‖y‖²−2x·yᵀ \
         with f32 dot tiles + f64 accumulators (estimator::flash)",
    );
    let threaded = TileConfig::default();
    table.note(&format!(
        "threaded = up to {} threads, {}x{} tiles",
        threaded.threads, threaded.block_q, threaded.block_t
    ));
    for &n in sizes {
        let m = (n / 8).max(1);
        let mut scalar_sum = 0.0f64;
        let mut flash1_sum = 0.0f64;
        let mut flashn_sum = 0.0f64;
        for seed in 0..seeds {
            let mut rng = Pcg64::new(42 + seed, 77);
            let x = mix.sample(n, &mut rng);
            let y = mix.sample(m, &mut rng);
            let w = vec![1.0f32; n];
            let h = bandwidth::sdkde_rate(&x, n, d);
            let hs = bandwidth::score_bandwidth(h);

            if n <= naive_max_n {
                scalar_sum += measure("scalar", spec, || {
                    black_box(native::sdkde(&x, &w, &y, d, h, hs));
                })
                .mean_ms();
            }
            let serial = TileConfig::serial();
            flash1_sum += measure("flash-1t", spec, || {
                black_box(flash::sdkde(&x, &w, &y, d, h, hs, &serial));
            })
            .mean_ms();
            flashn_sum += measure("flash-nt", spec, || {
                black_box(flash::sdkde(&x, &w, &y, d, h, hs, &threaded));
            })
            .mean_ms();
        }
        let scalar_ms =
            (n <= naive_max_n).then_some(scalar_sum / seeds as f64);
        let flash1_ms = flash1_sum / seeds as f64;
        let flashn_ms = flashn_sum / seeds as f64;

        table.row(vec![
            n.to_string(),
            scalar_ms.map(fmt_ms).unwrap_or_else(|| "-".into()),
            fmt_ms(flash1_ms),
            fmt_ms(flashn_ms),
            scalar_ms
                .map(|s| fmt_speedup(s / flash1_ms))
                .unwrap_or_else(|| "-".into()),
            scalar_ms
                .map(|s| fmt_speedup(s / flashn_ms))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    table
        .notes
        .push(format!("iters={} warmup={} seeds={seeds}", spec.iters, spec.warmup));
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_runs_without_artifacts() {
        let t = native_vs_scalar(RunSpec::new(0, 1), &[128], 256, 2).unwrap();
        assert_eq!(t.rows.len(), 1);
        // Scalar column populated (128 <= cap) and speedups parse as "x".
        assert_ne!(t.rows[0][1], "-");
        assert!(t.rows[0][4].ends_with('x'), "{:?}", t.rows[0]);
    }
}
