//! Experiment reporting: aligned console tables (the paper's rows/series)
//! plus CSV dumps under `target/bench_results/` for EXPERIMENTS.md.

use std::fmt::Write as _;
use std::path::PathBuf;

/// A rendered experiment table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each the headers' arity).
    pub rows: Vec<Vec<String>>,
    /// Free-form footnotes rendered under the table.
    pub notes: Vec<String>,
}

impl Table {
    /// Empty table with a caption and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Append a footnote.
    pub fn note(&mut self, note: &str) {
        self.notes.push(note.to_string());
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(
            out,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        for note in &self.notes {
            let _ = writeln!(out, "note: {note}");
        }
        out
    }

    /// CSV rendering (headers + rows; notes become # comments).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        for note in &self.notes {
            let _ = writeln!(out, "# {note}");
        }
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print to stdout and persist CSV under `target/bench_results/<id>.csv`.
    pub fn emit(&self, id: &str) {
        print!("{}", self.render());
        let dir = PathBuf::from("target/bench_results");
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{id}.csv"));
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warning: could not write {}: {e}", path.display());
            } else {
                println!("(csv: {})", path.display());
            }
        }
    }
}

/// Milliseconds with sensible precision for bench tables.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 1.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.3}")
    }
}

/// Speedup ratio, "12.3x".
pub fn fmt_speedup(ratio: f64) -> String {
    format!("{ratio:.2}x")
}

/// Scientific-ish error formatting for MISE/MIAE columns.
pub fn fmt_err(e: f64) -> String {
    format!("{e:.3e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["n", "runtime"]);
        t.row(vec!["512".into(), "1.5".into()]);
        t.row(vec!["131072".into(), "123.4".into()]);
        let r = t.render();
        assert!(r.contains("=== demo ==="));
        // Both rows end aligned on the right.
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[2].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("demo", &["name", "v"]);
        t.row(vec!["a,b".into(), "q\"q".into()]);
        t.note("hello");
        let csv = t.to_csv();
        assert!(csv.contains("# hello"));
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_ms(0.1234), "0.123");
        assert_eq!(fmt_ms(12.345), "12.35");
        assert_eq!(fmt_ms(1234.6), "1235");
        assert_eq!(fmt_speedup(47.0), "47.00x");
        assert!(fmt_err(0.000123).contains('e'));
    }
}
