//! Native Rust estimators: the scalar-loop baseline and correctness oracle.
//!
//! Two roles (DESIGN.md §3):
//!
//! 1. **Baseline** — the "scikit-learn KDE" analogue in the paper's Fig. 1 /
//!    Fig. 6 runtime comparisons: a straightforward O(n·m·d) scalar loop
//!    with no matrix-engine mapping.  Its absolute speed *is the point*;
//!    do not vectorize it beyond what a careful scalar implementation does.
//! 2. **Oracle** — integration tests cross-check the XLA runtime outputs
//!    against these implementations (they mirror python/compile/kernels/
//!    ref.py formula-for-formula, in f64 accumulation).

const TWO_PI: f64 = std::f64::consts::TAU;

/// Gaussian normalizer 1 / ((2 pi)^{d/2} h^d) — shared with the flash
/// kernels so oracle and backend can never disagree on normalization.
pub(crate) fn normalizer(h: f64, d: usize) -> f64 {
    (TWO_PI).powf(-(d as f64) / 2.0) * h.powi(-(d as i32))
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let diff = (*x - *y) as f64;
        acc += diff * diff;
    }
    acc
}

/// Weighted Gaussian KDE of `x` ([n, d] row-major) at `y` ([m, d]).
/// Returns `[m]` densities.  Mirrors `ref.kde_ref`.
pub fn kde(x: &[f32], w: &[f32], y: &[f32], d: usize, h: f64) -> Vec<f64> {
    let n = w.len();
    assert_eq!(x.len(), n * d);
    assert_eq!(y.len() % d, 0);
    let m = y.len() / d;
    let count: f64 = w.iter().map(|&v| v as f64).sum();
    assert!(count > 0.0, "no effective samples");
    let norm = normalizer(h, d) / count;
    let inv2h2 = 1.0 / (2.0 * h * h);

    let mut out = vec![0.0f64; m];
    for (j, o) in out.iter_mut().enumerate() {
        let yj = &y[j * d..(j + 1) * d];
        let mut acc = 0.0f64;
        for i in 0..n {
            let wi = w[i] as f64;
            if wi == 0.0 {
                continue;
            }
            let d2 = sq_dist(&x[i * d..(i + 1) * d], yj);
            acc += wi * (-d2 * inv2h2).exp();
        }
        *o = acc * norm;
    }
    out
}

/// Empirical score at each training point (bandwidth `h_s`).
/// Returns `[n, d]` row-major.  Mirrors `ref.score_ref`.
pub fn score(x: &[f32], w: &[f32], d: usize, h_s: f64) -> Vec<f64> {
    let n = w.len();
    assert_eq!(x.len(), n * d);
    let inv2h2 = 1.0 / (2.0 * h_s * h_s);
    let mut out = vec![0.0f64; n * d];
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let mut denom = 0.0f64;
        let mut numer = vec![0.0f64; d];
        for j in 0..n {
            let wj = w[j] as f64;
            if wj == 0.0 {
                continue;
            }
            let xj = &x[j * d..(j + 1) * d];
            let phi = wj * (-sq_dist(xi, xj) * inv2h2).exp();
            denom += phi;
            for (acc, &v) in numer.iter_mut().zip(xj) {
                *acc += phi * v as f64;
            }
        }
        // Guard matches ref.py / score.py / score_at(): 1e-30.  A smaller
        // guard (1e-300) lets a nearly-underflowed denominator survive and
        // blow up the score of far-outlier rows (see the regression test).
        let denom = denom.max(1e-30);
        for k in 0..d {
            out[i * d + k] =
                (numer[k] - xi[k] as f64 * denom) / (h_s * h_s * denom);
        }
    }
    out
}

/// Score of the weighted KDE of `x` evaluated at query rows `y`: [m, d]
/// row-major.  Mirrors `ref.score_at_ref` (guarded denominator — far-out
/// queries get ~0 scores rather than NaN).
pub fn score_at(x: &[f32], w: &[f32], y: &[f32], d: usize, h_s: f64) -> Vec<f64> {
    let n = w.len();
    assert_eq!(x.len(), n * d);
    assert_eq!(y.len() % d, 0);
    let m = y.len() / d;
    let inv2h2 = 1.0 / (2.0 * h_s * h_s);
    let mut out = vec![0.0f64; m * d];
    for q in 0..m {
        let yq = &y[q * d..(q + 1) * d];
        let mut denom = 0.0f64;
        let mut numer = vec![0.0f64; d];
        for i in 0..n {
            let wi = w[i] as f64;
            if wi == 0.0 {
                continue;
            }
            let xi = &x[i * d..(i + 1) * d];
            let phi = wi * (-sq_dist(yq, xi) * inv2h2).exp();
            denom += phi;
            for (acc, &v) in numer.iter_mut().zip(xi) {
                *acc += phi * v as f64;
            }
        }
        let denom = denom.max(1e-30);
        for k in 0..d {
            out[q * d + k] =
                (numer[k] - yq[k] as f64 * denom) / (h_s * h_s * denom);
        }
    }
    out
}

/// Debiased samples X^SD = X + (h^2/2) s(X); masked rows pass through.
/// Returns `[n, d]` f32 (matching the artifact wire format).
pub fn debias(x: &[f32], w: &[f32], d: usize, h: f64, h_s: f64) -> Vec<f32> {
    let n = w.len();
    let s = score(x, w, d, h_s);
    let shift = 0.5 * h * h;
    let mut out = x.to_vec();
    for i in 0..n {
        if w[i] == 0.0 {
            continue;
        }
        for k in 0..d {
            out[i * d + k] = (x[i * d + k] as f64 + shift * s[i * d + k]) as f32;
        }
    }
    out
}

/// Full SD-KDE: debias then evaluate.  Mirrors `ref.sdkde_ref`.
pub fn sdkde(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    d: usize,
    h: f64,
    h_s: f64,
) -> Vec<f64> {
    let x_sd = debias(x, w, d, h, h_s);
    kde(&x_sd, w, y, d, h)
}

/// Laplace-corrected KDE (signed).  Mirrors `ref.laplace_ref`.
pub fn laplace(x: &[f32], w: &[f32], y: &[f32], d: usize, h: f64) -> Vec<f64> {
    let n = w.len();
    assert_eq!(x.len(), n * d);
    let m = y.len() / d;
    let count: f64 = w.iter().map(|&v| v as f64).sum();
    assert!(count > 0.0);
    let norm = normalizer(h, d) / count;
    let inv2h2 = 1.0 / (2.0 * h * h);
    let half_d = d as f64 / 2.0;

    let mut out = vec![0.0f64; m];
    for (j, o) in out.iter_mut().enumerate() {
        let yj = &y[j * d..(j + 1) * d];
        let mut acc = 0.0f64;
        for i in 0..n {
            let wi = w[i] as f64;
            if wi == 0.0 {
                continue;
            }
            let d2 = sq_dist(&x[i * d..(i + 1) * d], yj);
            let scaled = d2 * inv2h2;
            acc += wi * (-scaled).exp() * (1.0 + half_d - scaled);
        }
        *o = acc * norm;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Pcg64::seeded(seed).normal_vec_f32(n * d)
    }

    #[test]
    fn kde_single_point_closed_form() {
        // One sample at origin, query at distance^2 = 0.25, h = 0.7, d = 2.
        let x = vec![0.0f32, 0.0];
        let w = vec![1.0f32];
        let y = vec![0.3f32, -0.4];
        let h = 0.7;
        let got = kde(&x, &w, &y, 2, h)[0];
        // Inputs are f32 (0.3, 0.4 are not exactly representable): compare
        // at f32-input precision.
        let want = (-0.25 / (2.0 * h * h)).exp() / (TWO_PI * h * h);
        assert!((got - want).abs() < 1e-7, "{got} vs {want}");
    }

    #[test]
    fn kde_integrates_to_one_1d() {
        let x = sample(40, 1, 1);
        let w = vec![1.0f32; 40];
        let lo = -8.0f64;
        let hi = 8.0f64;
        let steps = 4000;
        let dx = (hi - lo) / steps as f64;
        let grid: Vec<f32> =
            (0..=steps).map(|i| (lo + i as f64 * dx) as f32).collect();
        let pdf = kde(&x, &w, &grid, 1, 0.4);
        let integral: f64 = pdf.iter().sum::<f64>() * dx;
        assert!((integral - 1.0).abs() < 1e-3, "integral={integral}");
    }

    #[test]
    fn masked_rows_ignored() {
        let x = sample(30, 2, 2);
        let y = sample(5, 2, 3);
        let mut w = vec![1.0f32; 30];
        for i in 20..30 {
            w[i] = 0.0;
        }
        let masked = kde(&x, &w, &y, 2, 0.6);
        let trimmed = kde(&x[..40], &vec![1.0; 20], &y, 2, 0.6);
        for (a, b) in masked.iter().zip(&trimmed) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn score_zero_at_lone_sample() {
        let x = vec![1.0f32, -2.0];
        let w = vec![1.0f32];
        let s = score(&x, &w, 2, 0.5);
        assert!(s.iter().all(|v| v.abs() < 1e-9), "{s:?}");
    }

    #[test]
    fn score_points_toward_mode() {
        let n = 800;
        let x = sample(n, 1, 4);
        let w = vec![1.0f32; n];
        let s = score(&x, &w, 1, 0.35);
        // Correlation between position and score must be strongly negative.
        let mean_x: f64 = x.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let mean_s: f64 = s.iter().sum::<f64>() / n as f64;
        let mut cov = 0.0;
        let mut vx = 0.0;
        let mut vs = 0.0;
        for i in 0..n {
            let dx = x[i] as f64 - mean_x;
            let ds = s[i] - mean_s;
            cov += dx * ds;
            vx += dx * dx;
            vs += ds * ds;
        }
        let corr = cov / (vx.sqrt() * vs.sqrt());
        assert!(corr < -0.8, "corr={corr}");
    }

    #[test]
    fn score_far_outlier_guard_matches_ref() {
        // A masked far-outlier row: every kernel weight against the live
        // points is ~exp(-450) ≈ 1e-196 — above f64 underflow but far
        // below the ref.py guard of 1e-30.  With the guard at 1e-30 the
        // denominator clamps and the score collapses to -x_i / h_s²; the
        // old 1e-300 guard instead kept the tiny denominator and produced
        // (x̄ - x_i) / h_s², silently diverging from ref.py/score_at.
        let mut x: Vec<f32> = vec![4.0, 5.0, 6.0]; // live points near 5
        x.push(35.0); // outlier, 30 bandwidths away
        let mut w = vec![1.0f32; 3];
        w.push(0.0); // masked: only the guard decides its score
        let h_s = 1.0;
        let s = score(&x, &w, 1, h_s);
        let want = -35.0 / (h_s * h_s);
        assert!(
            (s[3] - want).abs() < 1e-6 * want.abs(),
            "outlier score {} vs guarded ref {}",
            s[3],
            want
        );
    }

    #[test]
    fn debias_masked_rows_pass_through() {
        let x = sample(20, 2, 5);
        let mut w = vec![1.0f32; 20];
        w[7] = 0.0;
        let out = debias(&x, &w, 2, 0.5, 0.35);
        assert_eq!(&out[14..16], &x[14..16]);
        assert_ne!(&out[0..2], &x[0..2]);
    }

    #[test]
    fn sdkde_beats_kde_on_smooth_density() {
        // The statistical claim at native scale: MSE to the true standard
        // normal improves after debiasing with an oversmoothed bandwidth.
        let n = 3000;
        let x = sample(n, 1, 6);
        let w = vec![1.0f32; n];
        let h = 0.45;
        let grid: Vec<f32> = (-20..=20).map(|i| i as f32 * 0.15).collect();
        let truth: Vec<f64> = grid
            .iter()
            .map(|&g| (-0.5 * (g as f64) * (g as f64)).exp() / TWO_PI.sqrt())
            .collect();
        let mse = |est: &[f64]| -> f64 {
            est.iter()
                .zip(&truth)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                / truth.len() as f64
        };
        let plain = kde(&x, &w, &grid, 1, h);
        let debiased = sdkde(&x, &w, &grid, 1, h, h / std::f64::consts::SQRT_2);
        assert!(mse(&debiased) < mse(&plain));
    }

    #[test]
    fn laplace_matches_kde_plus_correction_structure() {
        let x = sample(50, 3, 7);
        let w = vec![1.0f32; 50];
        let y = sample(9, 3, 8);
        let h = 0.8;
        let lc = laplace(&x, &w, &y, 3, h);
        let plain = kde(&x, &w, &y, 3, h);
        // Correction shifts but keeps the same scale.
        for (a, b) in lc.iter().zip(&plain) {
            assert!(a.is_finite());
            assert!((a - b).abs() < 10.0 * b.abs() + 1e-6);
        }
    }

    #[test]
    fn laplace_goes_negative_in_tail() {
        let x = vec![0.0f32; 8]; // 8 samples at the origin, d=1
        let w = vec![1.0f32; 8];
        let y = vec![2.5f32];
        let v = laplace(&x, &w, &y, 1, 1.0)[0];
        assert!(v < 0.0, "v={v}");
    }
}
