//! Native flash kernels: the paper's matmul reordering, on CPU.
//!
//! The scalar oracle in [`super::native`] walks every (query, train) pair
//! and recomputes `‖y − x‖²` coordinate-by-coordinate.  These kernels
//! apply the paper's core identity
//!
//! ```text
//! ‖y − x‖² = ‖y‖² + ‖x‖² − 2·y·xᵀ
//! ```
//!
//! so the O(n·m·d) inner sweep becomes GEMM structure: the cross term is a
//! blocked matrix multiply over f32 tiles (the CPU analogue of the paper's
//! tensor-core mapping), while the squared norms and every per-row
//! reduction are carried in f64 (the "f32 tiles, f64 accumulators" policy;
//! DESIGN.md §10/§11 document the resulting tolerance vs the scalar
//! oracle).
//!
//! Two inner-loop implementations exist behind [`TileConfig::simd`]:
//!
//! * **auto-vec** (always compiled) — unit-stride FMA loops the compiler
//!   vectorizes on its own; this was the PR 2 kernel.
//! * **explicit SIMD** (`simd` cargo feature, nightly `std::simd`) —
//!   `f32x8` lanes for the dot tile (element-for-element the same
//!   arithmetic as the scalar loop, so results are bit-identical across
//!   the flag) and `f64x4` lanes for the density exp/accumulate loop
//!   (`exp` applied per lane; lane partial sums re-associate the f64
//!   reduction, so densities agree with the auto-vec path only up to f64
//!   re-association noise, ~1e-15 relative).  The score kernels
//!   vectorize only their dot tile, keeping the gradient accumulation
//!   scalar and therefore invariant across the flag.
//!
//! The per-dataset precomputation — transposed train matrix, squared
//! norms, f64 weights — is factored into [`PreparedTrain`] so resident
//! models can pay it once: the `*_prepared` entry points are what the
//! native backend's prepare cache calls on the serving hot path
//! (DESIGN.md §11), while the plain entry points (`kde`, `score_at`, …)
//! prepare internally and remain the one-shot convenience surface.
//!
//! Query blocks are independent, so each kernel splits them across scoped
//! worker threads ([`TileConfig::threads`]; small problems stay serial).
//! Thread partitioning never touches a query row's arithmetic, so results
//! are bit-identical across thread counts.  On the auto-vec path, tile
//! sizes are bit-invariant too: each pair's dot product accumulates in k
//! order regardless of tile boundaries, and the density/score reductions
//! thread one running f64 accumulator through the tiles in strict
//! train-row order — so `block_q`/`block_t` never move a result bit,
//! which is what lets the autotuner ([`crate::tuner`]) apply
//! table-chosen block shapes with zero numeric consequence.  The
//! explicit-SIMD density accumulate carries lane partial sums whose
//! grouping follows the tile width, so under the `simd` flag tile
//! choices agree only up to f64 re-association noise (~1e-15 relative).
//! The conformance suite pins all of these properties down.
//!
//! Formulas mirror `python/compile/kernels/ref.py` exactly like the
//! scalar oracle does (same normalizers, same masked-row semantics, same
//! `1e-30` denominator guard in the score kernels).

use super::native::normalizer;

/// Tiling / parallelism knobs for the native kernels.
///
/// `block_q` × `block_t` is the (query rows × train rows) tile the dot
/// products are materialized for — the BLOCK_M × BLOCK_N analogue of the
/// paper's launch-parameter sweep.  `threads` is an *upper bound* on the
/// scoped threads query blocks are split across; problems below the
/// internal `MIN_PAIRS_PER_THREAD` floor per worker run serially, and `1`
/// always does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Query rows per tile (BLOCK_M analogue).
    pub block_q: usize,
    /// Train rows per tile (BLOCK_N analogue).
    pub block_t: usize,
    /// Upper bound on scoped worker threads for query blocks.
    pub threads: usize,
    /// Run the explicit `std::simd` inner loops.  Only effective in
    /// builds with the `simd` cargo feature; without it the flag is
    /// ignored and the auto-vectorized loops run.  Defaults to the
    /// feature's presence, so the fastest compiled path serves.
    pub simd: bool,
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig {
            block_q: 32,
            block_t: 256,
            threads: default_threads(),
            simd: cfg!(feature = "simd"),
        }
    }
}

impl TileConfig {
    /// Serial configuration (deterministic single-thread runs / baselines).
    pub fn serial() -> Self {
        TileConfig { threads: 1, ..TileConfig::default() }
    }

    /// Serial configuration with the explicit-SIMD loops disabled — the
    /// PR 2 auto-vectorized tile, kept callable for the bench series and
    /// the SIMD-agreement conformance property.
    pub fn scalar_tiles() -> Self {
        TileConfig { simd: false, ..TileConfig::serial() }
    }

    /// Clamp degenerate fields to the kernels' floor (every shape field
    /// ≥ 1).  Kernels apply this at entry; the tuner's candidate
    /// enumeration prunes on the same constraints (a candidate this
    /// method would alter is degenerate and never measured).
    pub fn checked(&self) -> TileConfig {
        TileConfig {
            block_q: self.block_q.max(1),
            block_t: self.block_t.max(1),
            threads: self.threads.max(1),
            simd: self.simd,
        }
    }
}

/// Default worker count: the machine's parallelism, capped so engine
/// workers stacking their own kernel threads cannot oversubscribe wildly.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Column-major copy of a row-major [n, d] buffer: `xt[k*n + i] = x[i*d + k]`.
/// Gives the tile GEMM unit-stride access over train rows.
fn transpose(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut xt = vec![0.0f32; n * d];
    for i in 0..n {
        for k in 0..d {
            xt[k * n + i] = x[i * d + k];
        }
    }
    xt
}

/// f64 squared row norms of a row-major [n, d] buffer (the exact half of
/// the matmul identity — f32 squares are exact in f64).
fn sq_norms(x: &[f32], n: usize, d: usize) -> Vec<f64> {
    (0..n)
        .map(|i| {
            x[i * d..(i + 1) * d]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum()
        })
        .collect()
}

/// Precomputed per-dataset state for the train side of every kernel: the
/// transposed train matrix (unit-stride tile GEMM access), the f64
/// squared norms (the exact half of the matmul identity), the f64 weights
/// and their sum.
///
/// Building one is O(n·d) — a few percent of each chunk's GEMM work — so
/// resident models should build it **once** and reuse it across queries;
/// the native backend caches it keyed by the registry tensors' `Arc`
/// identity (DESIGN.md §11).  Construction is deterministic: kernels fed
/// a cached `PreparedTrain` return bit-identical results to a fresh one.
///
/// The struct owns copies of its inputs (including the row-major train
/// matrix, which the score kernels' numerator loop needs), so it holds no
/// borrow of — and keeps no `Arc` pinning — the registry's tensors.
#[derive(Debug, Clone)]
pub struct PreparedTrain {
    /// Row-major [n, d] train matrix (score-kernel numerator access).
    x: Vec<f32>,
    /// Column-major transpose of `x` (dot-tile access).
    xt: Vec<f32>,
    /// f64 squared row norms of `x`.
    sq_x: Vec<f64>,
    /// Weights widened to f64 (0.0 marks a masked row).
    wf: Vec<f64>,
    /// Sum of the weights (the kernel's effective sample count).
    count: f64,
    n: usize,
    d: usize,
}

impl PreparedTrain {
    /// Prepare a weighted train set: `x` is row-major `[n, d]` with
    /// `n = w.len()`; `w == 0.0` marks a masked (padded) row exactly as
    /// in the one-shot kernels.
    pub fn new(x: &[f32], w: &[f32], d: usize) -> PreparedTrain {
        assert!(d >= 1, "dimension must be >= 1");
        let n = w.len();
        assert_eq!(x.len(), n * d, "x must be [n, d] row-major");
        PreparedTrain {
            x: x.to_vec(),
            xt: transpose(x, n, d),
            sq_x: sq_norms(x, n, d),
            wf: w.iter().map(|&v| v as f64).collect(),
            count: w.iter().map(|&v| v as f64).sum(),
            n,
            d,
        }
    }

    /// Train rows (including masked ones).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Data dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Sum of the weights (0.0 means every row is masked — the kernels
    /// reject such a train set).
    pub fn count(&self) -> f64 {
        self.count
    }

    /// Approximate resident size in bytes (cache accounting / stats).
    pub fn bytes(&self) -> usize {
        self.x.len() * 4
            + self.xt.len() * 4
            + (self.sq_x.len() + self.wf.len()) * 8
    }
}

/// Fill `dots[q*bt + t]` with `y_{q0+q} · x_{t0+t}` for a
/// `(q0, bq) × (t0, bt)` tile — auto-vectorized implementation.
///
/// Loop order k → q → t keeps the transposed train column resident across
/// all `bq` query rows and makes the innermost loop a unit-stride FMA the
/// compiler can vectorize — this is the micro-GEMM at the heart of the
/// reordering.
#[inline]
fn dot_tile_scalar(
    y: &[f32],
    xt: &[f32],
    n: usize,
    d: usize,
    (q0, bq): (usize, usize),
    (t0, bt): (usize, usize),
    dots: &mut [f32],
) {
    dots[..bq * bt].fill(0.0);
    for k in 0..d {
        let col = &xt[k * n + t0..k * n + t0 + bt];
        for q in 0..bq {
            let yk = y[(q0 + q) * d + k];
            let row = &mut dots[q * bt..q * bt + bt];
            for (dst, &xv) in row.iter_mut().zip(col) {
                *dst += yk * xv;
            }
        }
    }
}

/// Dot-tile dispatch: explicit `f32x8` lanes when the build has them and
/// the config asks, the auto-vectorized loop otherwise.  Both compute the
/// identical per-element operation sequence, so the choice never moves a
/// result bit.
#[inline]
#[allow(clippy::too_many_arguments)]
fn dot_tile(
    use_simd: bool,
    y: &[f32],
    xt: &[f32],
    n: usize,
    d: usize,
    q: (usize, usize),
    t: (usize, usize),
    dots: &mut [f32],
) {
    #[cfg(feature = "simd")]
    {
        if use_simd {
            simd::dot_tile(y, xt, n, d, q, t, dots);
            return;
        }
    }
    let _ = use_simd;
    dot_tile_scalar(y, xt, n, d, q, t, dots);
}

/// One query row's density accumulation over a train tile — scalar
/// implementation (masked rows skipped).  Takes the running accumulator
/// `acc` and folds the tile's terms into it **in train-row order**, so
/// the full reduction over all tiles is one strictly sequential f64 sum
/// — tile boundaries never regroup it, which makes densities bit-exact
/// across `block_t` choices on this path (the tuner's invariance
/// contract).
#[inline]
#[allow(clippy::too_many_arguments)]
fn density_row_scalar(
    acc: f64,
    sq_y: f64,
    sq_x: &[f64],
    wf: &[f64],
    dots: &[f32],
    inv2h2: f64,
    half_d: f64,
    laplace_term: bool,
) -> f64 {
    let mut a = acc;
    for t in 0..dots.len() {
        let wi = wf[t];
        if wi == 0.0 {
            continue;
        }
        let d2 = (sq_y + sq_x[t] - 2.0 * dots[t] as f64).max(0.0);
        let scaled = d2 * inv2h2;
        let e = (-scaled).exp();
        a += if laplace_term {
            wi * e * (1.0 + half_d - scaled)
        } else {
            wi * e
        };
    }
    a
}

/// Density accumulation dispatch.  The scalar path threads `acc`
/// through the tile in strict train-row order (bit-exact across tile
/// sizes); the SIMD path evaluates masked rows as exact `+0.0` terms
/// instead of skipping them and carries four f64 lane accumulators whose
/// tile partial is added to `acc`, so it agrees with the scalar path —
/// and with itself across tile sizes — only up to f64 re-association.
#[inline]
#[allow(clippy::too_many_arguments)]
fn density_row(
    use_simd: bool,
    acc: f64,
    sq_y: f64,
    sq_x: &[f64],
    wf: &[f64],
    dots: &[f32],
    inv2h2: f64,
    half_d: f64,
    laplace_term: bool,
) -> f64 {
    #[cfg(feature = "simd")]
    {
        if use_simd {
            return acc
                + simd::density_row(
                    sq_y, sq_x, wf, dots, inv2h2, half_d, laplace_term,
                );
        }
    }
    let _ = use_simd;
    density_row_scalar(acc, sq_y, sq_x, wf, dots, inv2h2, half_d, laplace_term)
}

/// Explicit `std::simd` inner loops (nightly portable SIMD, `simd` cargo
/// feature).  DESIGN.md §11 states the numerics contract: the dot tile is
/// element-for-element the scalar arithmetic on `f32x8` lanes (bit-equal
/// across the flag); the density accumulate runs `f64x4` lanes with `exp`
/// applied per lane, re-associating the f64 reduction within a tile.
#[cfg(feature = "simd")]
mod simd {
    use std::simd::prelude::*;

    const F32_LANES: usize = 8;
    const F64_LANES: usize = 4;

    pub(super) fn dot_tile(
        y: &[f32],
        xt: &[f32],
        n: usize,
        d: usize,
        (q0, bq): (usize, usize),
        (t0, bt): (usize, usize),
        dots: &mut [f32],
    ) {
        dots[..bq * bt].fill(0.0);
        for k in 0..d {
            let col = &xt[k * n + t0..k * n + t0 + bt];
            for q in 0..bq {
                let yk = y[(q0 + q) * d + k];
                let ykv = f32x8::splat(yk);
                let row = &mut dots[q * bt..q * bt + bt];
                let mut t = 0usize;
                while t + F32_LANES <= bt {
                    let c = f32x8::from_slice(&col[t..]);
                    let r = f32x8::from_slice(&row[t..]);
                    (r + ykv * c).copy_to_slice(&mut row[t..t + F32_LANES]);
                    t += F32_LANES;
                }
                while t < bt {
                    row[t] += yk * col[t];
                    t += 1;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn density_row(
        sq_y: f64,
        sq_x: &[f64],
        wf: &[f64],
        dots: &[f32],
        inv2h2: f64,
        half_d: f64,
        laplace_term: bool,
    ) -> f64 {
        let bt = dots.len();
        let sqy = f64x4::splat(sq_y);
        let zero = f64x4::splat(0.0);
        let two = f64x4::splat(2.0);
        let inv = f64x4::splat(inv2h2);
        let hd1 = f64x4::splat(1.0 + half_d);
        let mut acc = f64x4::splat(0.0);
        let mut t = 0usize;
        while t + F64_LANES <= bt {
            let dv = f64x4::from_array([
                dots[t] as f64,
                dots[t + 1] as f64,
                dots[t + 2] as f64,
                dots[t + 3] as f64,
            ]);
            let sx = f64x4::from_slice(&sq_x[t..]);
            let d2 = (sqy + sx - two * dv).simd_max(zero);
            let scaled = d2 * inv;
            let mut ea = scaled.to_array();
            for v in &mut ea {
                *v = (-*v).exp();
            }
            let e = f64x4::from_array(ea);
            let w = f64x4::from_slice(&wf[t..]);
            acc += if laplace_term { w * e * (hd1 - scaled) } else { w * e };
            t += F64_LANES;
        }
        let a = acc.to_array();
        // Scalar tail for the last `bt % 4` rows: delegate to the one
        // scalar implementation (accumulator seeded at 0 — this returns
        // the tile partial, re-associated by the lanes above) so the
        // term formula lives in one place.
        a[0] + a[1]
            + a[2]
            + a[3]
            + super::density_row_scalar(
                0.0,
                sq_y,
                &sq_x[t..bt],
                &wf[t..bt],
                &dots[t..],
                inv2h2,
                half_d,
                laplace_term,
            )
    }
}

/// Minimum (query, train) pairs per worker thread: below this, spawn+join
/// overhead (tens of µs per thread) outweighs the compute, so small
/// requests — the serving hot path for padded 32-row buckets — run
/// serially.  Thread count never changes results (each query row's
/// arithmetic is independent of the partition).
const MIN_PAIRS_PER_THREAD: usize = 32 * 1024;

/// Split `rows` query rows (each `width` output values wide) across up to
/// `threads` scoped threads — scaled down so every thread gets at least
/// [`MIN_PAIRS_PER_THREAD`] of the `pairs` total — handing every thread a
/// contiguous `(q_start, q_end, out_chunk)` span.
fn par_query_rows<F>(
    out: &mut [f64],
    rows: usize,
    width: usize,
    pairs: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, usize, &mut [f64]) + Sync,
{
    let threads = threads
        .max(1)
        .min(rows.max(1))
        .min((pairs / MIN_PAIRS_PER_THREAD).max(1));
    if threads <= 1 {
        f(0, rows, out);
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [f64] = out;
        let mut q0 = 0usize;
        let f = &f;
        while q0 < rows {
            let q1 = (q0 + per).min(rows);
            // Detach the span from `rest` so it can cross into the thread
            // while the tail keeps being split.
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut((q1 - q0) * width);
            rest = tail;
            scope.spawn(move || f(q0, q1, chunk));
            q0 = q1;
        }
    });
}

/// Weighted Gaussian KDE via the matmul identity.  Same contract as
/// [`super::native::kde`]: x [n, d], w [n], y [m, d] row-major, returns
/// [m] f64 densities.  One-shot: prepares the train side internally; use
/// [`kde_prepared`] to amortize that over many query batches.
pub fn kde(x: &[f32], w: &[f32], y: &[f32], d: usize, h: f64, cfg: &TileConfig) -> Vec<f64> {
    kde_prepared(&PreparedTrain::new(x, w, d), y, h, cfg)
}

/// [`kde`] over an already-[`PreparedTrain`] train side.
pub fn kde_prepared(
    train: &PreparedTrain,
    y: &[f32],
    h: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    density(train, y, h, false, cfg)
}

/// Laplace-corrected KDE (signed).  Mirrors [`super::native::laplace`].
pub fn laplace(x: &[f32], w: &[f32], y: &[f32], d: usize, h: f64, cfg: &TileConfig) -> Vec<f64> {
    laplace_prepared(&PreparedTrain::new(x, w, d), y, h, cfg)
}

/// [`laplace`] over an already-[`PreparedTrain`] train side.
pub fn laplace_prepared(
    train: &PreparedTrain,
    y: &[f32],
    h: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    density(train, y, h, true, cfg)
}

fn density(
    train: &PreparedTrain,
    y: &[f32],
    h: f64,
    laplace_term: bool,
    cfg: &TileConfig,
) -> Vec<f64> {
    assert!(train.count > 0.0, "no effective samples");
    let norm = normalizer(h, train.d) / train.count;
    kernel_sum(train, y, &train.wf, norm, h, laplace_term, cfg)
}

/// Core blocked sweep shared by the density kernels and [`matvec`]:
///
/// ```text
/// out_q = scale · Σ_t  weff[t] · term(‖y_q − x_t‖² / (2h²))
/// ```
///
/// where `term` is the Gaussian exponential (or its Laplace-corrected
/// form) and `weff` is a per-train-row effective weight of length `n`.
/// The density kernels pass `weff = train.wf` and `scale = normalizer /
/// count` — byte-for-byte the historical loop, so densities are bitwise
/// unaffected by this factoring.  MatVec passes `weff[t] = wf[t]·v[t]`
/// and `scale = 1.0`, riding the identical tile/accumulate discipline
/// (and therefore the same block-shape/thread invariance contract).
fn kernel_sum(
    train: &PreparedTrain,
    y: &[f32],
    weff: &[f64],
    scale: f64,
    h: f64,
    laplace_term: bool,
    cfg: &TileConfig,
) -> Vec<f64> {
    let cfg = cfg.checked();
    let d = train.d;
    assert_eq!(y.len() % d, 0, "y must be [m, d] row-major");
    assert_eq!(weff.len(), train.n, "weff must be [n]");
    let m = y.len() / d;
    let sq_y = sq_norms(y, m, d);
    let inv2h2 = 1.0 / (2.0 * h * h);
    let half_d = d as f64 / 2.0;
    let n = train.n;

    let mut out = vec![0.0f64; m];
    par_query_rows(&mut out, m, 1, m * n, cfg.threads, |qa, qb, chunk| {
        let mut dots = vec![0.0f32; cfg.block_q * cfg.block_t];
        let mut q0 = qa;
        while q0 < qb {
            let bq = cfg.block_q.min(qb - q0);
            let mut acc = vec![0.0f64; bq];
            let mut t0 = 0usize;
            while t0 < n {
                let bt = cfg.block_t.min(n - t0);
                dot_tile(cfg.simd, y, &train.xt, n, d, (q0, bq), (t0, bt), &mut dots);
                for (q, a) in acc.iter_mut().enumerate() {
                    *a = density_row(
                        cfg.simd,
                        *a,
                        sq_y[q0 + q],
                        &train.sq_x[t0..t0 + bt],
                        &weff[t0..t0 + bt],
                        &dots[q * bt..q * bt + bt],
                        inv2h2,
                        half_d,
                        laplace_term,
                    );
                }
                t0 += bt;
            }
            for q in 0..bq {
                chunk[q0 + q - qa] = acc[q] * scale;
            }
            q0 += bq;
        }
    });
    out
}

/// Weighted kernel matrix–vector product over the Gaussian kernel:
///
/// ```text
/// out_q = Σ_j  w_j · v_j · exp(−‖y_q − x_j‖² / (2h²))
/// ```
///
/// i.e. `K·v` for the (masked, weighted) kernel matrix `K[q][j] =
/// w_j·exp(−‖y_q−x_j‖²/(2h²))` — **unnormalized**: no `(2πh²)^{-d/2}` or
/// `1/Σw` factor, because the linalg ops ([`crate::linalg`]) compose raw
/// kernel sums and apply their own normalization.  Masked rows
/// (`w_j == 0`) contribute nothing regardless of `v_j`, so a padded
/// bucket with zeroed `v` tail is exactly the un-padded product.
/// One-shot; see [`matvec_prepared`] for the cached-train entry point.
pub fn matvec(
    x: &[f32],
    w: &[f32],
    v: &[f32],
    y: &[f32],
    d: usize,
    h: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    matvec_prepared(&PreparedTrain::new(x, w, d), v, y, h, cfg)
}

/// [`matvec`] over an already-[`PreparedTrain`] train side.  `v` must be
/// `[n]` (one entry per train row, masked rows included).  Runs the same
/// blocked f32-dot / f64-accumulate sweep as the density kernels, so the
/// result carries the identical invariance contract: bit-exact across
/// `block_q`/`block_t`/`threads` on the auto-vec path, f64
/// re-association noise (~1e-15) only under the `simd` flag.
pub fn matvec_prepared(
    train: &PreparedTrain,
    v: &[f32],
    y: &[f32],
    h: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    assert_eq!(v.len(), train.n, "v must be [n] (one entry per train row)");
    let weff: Vec<f64> = train
        .wf
        .iter()
        .zip(v)
        .map(|(&wi, &vi)| wi * vi as f64)
        .collect();
    kernel_sum(train, y, &weff, 1.0, h, false, cfg)
}

/// Score of the weighted KDE of `x` at query rows `y` — the flash twin of
/// [`super::native::score_at`] (and, with `y = x`, of
/// [`super::native::score`]): returns [m, d] row-major f64, `1e-30`
/// denominator guard.  One-shot; see [`score_at_prepared`].
pub fn score_at(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    d: usize,
    h_s: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    score_at_prepared(&PreparedTrain::new(x, w, d), y, h_s, cfg)
}

/// [`score_at`] over an already-[`PreparedTrain`] train side.
///
/// Only the dot tile runs SIMD lanes here; the gradient accumulation
/// (denominator + d-wide numerator) stays scalar, so score results are
/// identical whichever inner loop serves the dot tile.
pub fn score_at_prepared(
    train: &PreparedTrain,
    y: &[f32],
    h_s: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    let cfg = cfg.checked();
    let d = train.d;
    assert_eq!(y.len() % d, 0, "y must be [m, d] row-major");
    let m = y.len() / d;
    let sq_y = sq_norms(y, m, d);
    let inv2h2 = 1.0 / (2.0 * h_s * h_s);
    let n = train.n;

    let mut out = vec![0.0f64; m * d];
    par_query_rows(&mut out, m, d, m * n, cfg.threads, |qa, qb, chunk| {
        let mut dots = vec![0.0f32; cfg.block_q * cfg.block_t];
        let mut q0 = qa;
        while q0 < qb {
            let bq = cfg.block_q.min(qb - q0);
            let mut denom = vec![0.0f64; bq];
            let mut numer = vec![0.0f64; bq * d];
            let mut t0 = 0usize;
            while t0 < n {
                let bt = cfg.block_t.min(n - t0);
                dot_tile(cfg.simd, y, &train.xt, n, d, (q0, bq), (t0, bt), &mut dots);
                for q in 0..bq {
                    let sq_yq = sq_y[q0 + q];
                    let numer_q = &mut numer[q * d..(q + 1) * d];
                    for t in 0..bt {
                        let wi = train.wf[t0 + t];
                        if wi == 0.0 {
                            continue;
                        }
                        let d2 = (sq_yq + train.sq_x[t0 + t]
                            - 2.0 * dots[q * bt + t] as f64)
                            .max(0.0);
                        let phi = wi * (-d2 * inv2h2).exp();
                        denom[q] += phi;
                        let xi = &train.x[(t0 + t) * d..(t0 + t + 1) * d];
                        for (acc, &v) in numer_q.iter_mut().zip(xi) {
                            *acc += phi * v as f64;
                        }
                    }
                }
                t0 += bt;
            }
            for q in 0..bq {
                let dq = denom[q].max(1e-30);
                let yq = &y[(q0 + q) * d..(q0 + q + 1) * d];
                for k in 0..d {
                    chunk[(q0 + q - qa) * d + k] =
                        (numer[q * d + k] - yq[k] as f64 * dq) / (h_s * h_s * dq);
                }
            }
            q0 += bq;
        }
    });
    out
}

/// Debiased samples X^SD = X + (h²/2)·s(X); masked rows pass through.
/// Mirrors [`super::native::debias`] (f32 output, the artifact wire format).
pub fn debias(x: &[f32], w: &[f32], d: usize, h: f64, h_s: f64, cfg: &TileConfig) -> Vec<f32> {
    debias_prepared(&PreparedTrain::new(x, w, d), h, h_s, cfg)
}

/// [`debias`] over an already-[`PreparedTrain`] train side (the prepared
/// matrix doubles as the query set: the score pass runs at `y = x`).
pub fn debias_prepared(
    train: &PreparedTrain,
    h: f64,
    h_s: f64,
    cfg: &TileConfig,
) -> Vec<f32> {
    let d = train.d;
    let s = score_at_prepared(train, &train.x, h_s, cfg);
    let shift = 0.5 * h * h;
    let mut out = train.x.clone();
    for i in 0..train.n {
        if train.wf[i] == 0.0 {
            continue;
        }
        for k in 0..d {
            out[i * d + k] =
                (train.x[i * d + k] as f64 + shift * s[i * d + k]) as f32;
        }
    }
    out
}

/// Full SD-KDE: debias then evaluate.  Mirrors [`super::native::sdkde`].
pub fn sdkde(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    d: usize,
    h: f64,
    h_s: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    let x_sd = debias(x, w, d, h, h_s, cfg);
    kde(&x_sd, w, y, d, h, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::native;
    use crate::util::rng::Pcg64;

    fn sample(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Pcg64::seeded(seed).normal_vec_f32(n * d)
    }

    fn assert_close(a: &[f64], b: &[f64], rtol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let rel = (x - y).abs() / y.abs().max(1e-30);
            assert!(rel < rtol, "row {i}: {x} vs {y} (rel {rel:.2e})");
        }
    }

    #[test]
    fn kde_matches_oracle_small() {
        let (n, m, d) = (97, 23, 3);
        let x = sample(n, d, 1);
        let y = sample(m, d, 2);
        let w = vec![1.0f32; n];
        let got = kde(&x, &w, &y, d, 0.6, &TileConfig::default());
        let want = native::kde(&x, &w, &y, d, 0.6);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn single_point_closed_form() {
        let x = vec![0.0f32, 0.0];
        let w = vec![1.0f32];
        let y = vec![0.3f32, -0.4];
        let h = 0.7;
        let got = kde(&x, &w, &y, 2, h, &TileConfig::serial())[0];
        let tau = std::f64::consts::TAU;
        let want = (-0.25 / (2.0 * h * h)).exp() / (tau * h * h);
        assert!((got - want).abs() < 1e-7, "{got} vs {want}");
    }

    #[test]
    fn tiles_smaller_than_problem_still_cover_all_pairs() {
        let (n, m, d) = (53, 17, 1);
        let x = sample(n, d, 3);
        let y = sample(m, d, 4);
        let w = vec![1.0f32; n];
        let tiny =
            TileConfig { block_q: 2, block_t: 3, ..TileConfig::serial() };
        let got = kde(&x, &w, &y, d, 0.4, &tiny);
        let want = native::kde(&x, &w, &y, d, 0.4);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn score_at_matches_oracle() {
        let (n, m, d) = (64, 9, 2);
        let x = sample(n, d, 5);
        let y = sample(m, d, 6);
        let w = vec![1.0f32; n];
        let got = score_at(&x, &w, &y, d, 0.5, &TileConfig::default());
        let want = native::score_at(&x, &w, &y, d, 0.5);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn transpose_round_trip() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let xt = transpose(&x, 3, 2);
        assert_eq!(xt, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn block_shapes_are_bitwise_invariant_on_the_autovec_path() {
        // The tuner's contract: applying table-chosen block_q/block_t
        // must never move a result bit.  On the auto-vec path the
        // density reduction is strictly train-row-sequential and the
        // score reductions always were, so any block shape — including
        // odd, non-power-of-two ones — is bit-exact against the default.
        let (n, m, d) = (157, 29, 3);
        let x = sample(n, d, 21);
        let y = sample(m, d, 22);
        let mut w = vec![1.0f32; n];
        w[5] = 0.0;
        let base = TileConfig::scalar_tiles();
        for (bq, bt) in [(1, 1), (5, 7), (64, 33), (256, 1024)] {
            let cfg = TileConfig { block_q: bq, block_t: bt, ..base };
            assert_eq!(
                kde(&x, &w, &y, d, 0.5, &cfg),
                kde(&x, &w, &y, d, 0.5, &base),
                "kde moved at blocks {bq}x{bt}"
            );
            assert_eq!(
                laplace(&x, &w, &y, d, 0.5, &cfg),
                laplace(&x, &w, &y, d, 0.5, &base),
                "laplace moved at blocks {bq}x{bt}"
            );
            assert_eq!(
                score_at(&x, &w, &y, d, 0.4, &cfg),
                score_at(&x, &w, &y, d, 0.4, &base),
                "score moved at blocks {bq}x{bt}"
            );
        }
    }

    #[test]
    fn threads_do_not_change_results() {
        // Big enough that MIN_PAIRS_PER_THREAD actually admits 4 workers.
        let (n, m, d) = (600, 256, 4);
        assert!(n * m / MIN_PAIRS_PER_THREAD >= 4);
        let x = sample(n, d, 7);
        let y = sample(m, d, 8);
        let w = vec![1.0f32; n];
        let serial = kde(&x, &w, &y, d, 0.7, &TileConfig::serial());
        let threaded =
            kde(&x, &w, &y, d, 0.7, &TileConfig { threads: 4, ..TileConfig::default() });
        // Thread partitioning only splits query rows: bit-identical.
        assert_eq!(serial, threaded);
    }

    #[test]
    fn small_problems_run_serially_but_correctly() {
        // Below the pairs floor the kernel must not spawn (latency), and
        // results are the same either way.
        let (n, m, d) = (40, 8, 2);
        let x = sample(n, d, 9);
        let y = sample(m, d, 10);
        let w = vec![1.0f32; n];
        let a = kde(&x, &w, &y, d, 0.5, &TileConfig { threads: 16, ..TileConfig::default() });
        let b = kde(&x, &w, &y, d, 0.5, &TileConfig::serial());
        assert_eq!(a, b);
    }

    #[test]
    fn prepared_entry_points_are_bitwise_identical_to_oneshot() {
        // The cache-hit contract: a PreparedTrain built once and reused
        // must give exactly what the one-shot entry points compute.
        let (n, m, d) = (150, 31, 3);
        let x = sample(n, d, 11);
        let y = sample(m, d, 12);
        let mut w = vec![1.0f32; n];
        w[7] = 0.0;
        w[n - 1] = 0.0;
        let cfg = TileConfig::default();
        let train = PreparedTrain::new(&x, &w, d);
        assert_eq!(train.n(), n);
        assert_eq!(train.d(), d);
        assert!(train.count() > 0.0 && train.bytes() > 0);

        for _ in 0..2 {
            // Twice: reuse must not mutate the prepared state.
            assert_eq!(
                kde_prepared(&train, &y, 0.5, &cfg),
                kde(&x, &w, &y, d, 0.5, &cfg)
            );
            assert_eq!(
                laplace_prepared(&train, &y, 0.5, &cfg),
                laplace(&x, &w, &y, d, 0.5, &cfg)
            );
            assert_eq!(
                score_at_prepared(&train, &y, 0.4, &cfg),
                score_at(&x, &w, &y, d, 0.4, &cfg)
            );
            assert_eq!(
                debias_prepared(&train, 0.5, 0.35, &cfg),
                debias(&x, &w, d, 0.5, 0.35, &cfg)
            );
        }
    }

    /// Dense scalar MatVec oracle: materialize K row by row, multiply.
    fn matvec_oracle(
        x: &[f32],
        w: &[f32],
        v: &[f32],
        y: &[f32],
        d: usize,
        h: f64,
    ) -> Vec<f64> {
        let n = w.len();
        let m = y.len() / d;
        let inv2h2 = 1.0 / (2.0 * h * h);
        let mut out = vec![0.0f64; m];
        for (q, o) in out.iter_mut().enumerate() {
            let yq = &y[q * d..(q + 1) * d];
            for j in 0..n {
                let mut d2 = 0.0f64;
                for k in 0..d {
                    let diff = (yq[k] - x[j * d + k]) as f64;
                    d2 += diff * diff;
                }
                *o += w[j] as f64
                    * v[j] as f64
                    * (-d2 * inv2h2).exp();
            }
        }
        out
    }

    #[test]
    fn matvec_matches_dense_oracle() {
        let (n, m, d) = (113, 19, 3);
        let x = sample(n, d, 31);
        let y = sample(m, d, 32);
        let v = sample(n, 1, 33);
        let mut w = vec![1.0f32; n];
        w[4] = 0.0;
        w[n - 1] = 0.0;
        let got = matvec(&x, &w, &v, &y, d, 0.6, &TileConfig::default());
        let want = matvec_oracle(&x, &w, &v, &y, d, 0.6);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn matvec_masked_rows_ignore_v() {
        // A masked row contributes nothing no matter what v holds there —
        // the padded-bucket contract the serving layer relies on.
        let (n, m, d) = (40, 7, 2);
        let x = sample(n, d, 34);
        let y = sample(m, d, 35);
        let mut w = vec![1.0f32; n];
        w[10] = 0.0;
        let v = vec![1.0f32; n];
        let mut v_poison = v.clone();
        v_poison[10] = 1.0e20;
        let cfg = TileConfig::serial();
        assert_eq!(
            matvec(&x, &w, &v, &y, d, 0.5, &cfg),
            matvec(&x, &w, &v_poison, &y, d, 0.5, &cfg),
        );
    }

    #[test]
    fn matvec_prepared_is_bitwise_identical_to_oneshot() {
        let (n, m, d) = (90, 13, 4);
        let x = sample(n, d, 36);
        let y = sample(m, d, 37);
        let v = sample(n, 1, 38);
        let w = vec![1.0f32; n];
        let cfg = TileConfig::default();
        let train = PreparedTrain::new(&x, &w, d);
        for _ in 0..2 {
            assert_eq!(
                matvec_prepared(&train, &v, &y, 0.5, &cfg),
                matvec(&x, &w, &v, &y, d, 0.5, &cfg)
            );
        }
    }

    #[test]
    fn matvec_block_shapes_are_bitwise_invariant_on_the_autovec_path() {
        // MatVec rides the same kernel_sum sweep as the densities, so it
        // inherits the tuner's invariance contract verbatim.
        let (n, m, d) = (157, 29, 3);
        let x = sample(n, d, 39);
        let y = sample(m, d, 40);
        let v = sample(n, 1, 41);
        let mut w = vec![1.0f32; n];
        w[5] = 0.0;
        let base = TileConfig::scalar_tiles();
        for (bq, bt) in [(1, 1), (5, 7), (64, 33), (256, 1024)] {
            let cfg = TileConfig { block_q: bq, block_t: bt, ..base };
            assert_eq!(
                matvec(&x, &w, &v, &y, d, 0.5, &cfg),
                matvec(&x, &w, &v, &y, d, 0.5, &base),
                "matvec moved at blocks {bq}x{bt}"
            );
        }
        // Threads split query rows only: bit-identical too.
        let threaded = TileConfig { threads: 4, ..base };
        assert_eq!(
            matvec(&x, &w, &v, &y, d, 0.5, &threaded),
            matvec(&x, &w, &v, &y, d, 0.5, &base),
        );
    }

    #[test]
    fn density_unchanged_by_kernel_sum_factoring() {
        // The refactor guard: densities through the generalized
        // kernel_sum must stay bitwise what the historical density()
        // loop produced — cross-check against the scalar oracle at the
        // established tolerance, and ones-vector MatVec against the
        // unnormalized kde sum.
        let (n, m, d) = (97, 23, 3);
        let x = sample(n, d, 1);
        let y = sample(m, d, 2);
        let w = vec![1.0f32; n];
        let cfg = TileConfig::scalar_tiles();
        let dens = kde(&x, &w, &y, d, 0.6, &cfg);
        let want = native::kde(&x, &w, &y, d, 0.6);
        assert_close(&dens, &want, 1e-4);
        // K·1 = count · density / normalizer.
        let ones = vec![1.0f32; n];
        let mv = matvec(&x, &w, &ones, &y, d, 0.6, &cfg);
        let norm = super::normalizer(0.6, d) / n as f64;
        let scaled: Vec<f64> = dens.iter().map(|v| v / norm).collect();
        assert_close(&mv, &scaled, 1e-12);
    }

    #[test]
    fn simd_flag_agrees_with_scalar_tiles() {
        // With the `simd` feature: the dot tile is bit-equal across the
        // flag and the density accumulate re-associates f64 partial sums
        // only.  Without the feature both flags run the same code, so the
        // test degenerates to exact equality — either way it must pass.
        let (n, m, d) = (213, 47, 16);
        let x = sample(n, d, 13);
        let y = sample(m, d, 14);
        let mut w = vec![1.0f32; n];
        w[3] = 0.0;
        let on = TileConfig { simd: true, ..TileConfig::serial() };
        let off = TileConfig::scalar_tiles();

        let a = kde(&x, &w, &y, d, 0.6, &on);
        let b = kde(&x, &w, &y, d, 0.6, &off);
        for (p, q) in a.iter().zip(&b) {
            let rel = (p - q).abs() / q.abs().max(1e-30);
            assert!(rel < 1e-12, "kde moved across simd flag: {p} vs {q}");
        }

        // Scores keep a scalar accumulate: agreement is far tighter than
        // re-association noise (bit-equal in practice).
        let a = score_at(&x, &w, &y, d, 0.5, &on);
        let b = score_at(&x, &w, &y, d, 0.5, &off);
        for (p, q) in a.iter().zip(&b) {
            let scale = q.abs().max(1.0);
            assert!(
                ((p - q) / scale).abs() < 1e-13,
                "score moved across simd flag: {p} vs {q}"
            );
        }

        // MatVec rides the density accumulate: same re-association bound.
        let v = sample(n, 1, 15);
        let a = matvec(&x, &w, &v, &y, d, 0.6, &on);
        let b = matvec(&x, &w, &v, &y, d, 0.6, &off);
        for (p, q) in a.iter().zip(&b) {
            let rel = (p - q).abs() / q.abs().max(1e-30);
            assert!(rel < 1e-12, "matvec moved across simd flag: {p} vs {q}");
        }
    }
}
