//! Native flash kernels: the paper's matmul reordering, on CPU.
//!
//! The scalar oracle in [`super::native`] walks every (query, train) pair
//! and recomputes `‖y − x‖²` coordinate-by-coordinate.  These kernels
//! apply the paper's core identity
//!
//! ```text
//! ‖y − x‖² = ‖y‖² + ‖x‖² − 2·y·xᵀ
//! ```
//!
//! so the O(n·m·d) inner sweep becomes GEMM structure: the cross term is a
//! blocked matrix multiply over f32 tiles (the CPU analogue of the paper's
//! tensor-core mapping — contiguous unit-stride FMA loops the compiler can
//! vectorize), while the squared norms and every per-row reduction are
//! carried in f64 (the "f32 tiles, f64 accumulators" policy; DESIGN.md
//! §10 documents the resulting tolerance vs the scalar oracle).
//!
//! Query blocks are independent, so each kernel splits them across scoped
//! worker threads ([`TileConfig::threads`]; small problems stay serial).
//! Thread partitioning never touches a query row's arithmetic, so results
//! are bit-identical across thread counts.  Tile sizes (`block_t`) do
//! regroup the f64 partial sums over train rows, so across tile choices
//! results agree only up to f64 re-association noise (~1e-15 relative) —
//! the conformance suite pins both properties down.
//!
//! Formulas mirror `python/compile/kernels/ref.py` exactly like the
//! scalar oracle does (same normalizers, same masked-row semantics, same
//! `1e-30` denominator guard in the score kernels).

use super::native::normalizer;

/// Tiling / parallelism knobs for the native kernels.
///
/// `block_q` × `block_t` is the (query rows × train rows) tile the dot
/// products are materialized for — the BLOCK_M × BLOCK_N analogue of the
/// paper's launch-parameter sweep.  `threads` is an *upper bound* on the
/// scoped threads query blocks are split across; problems below
/// [`MIN_PAIRS_PER_THREAD`] per worker run serially, and `1` always does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    pub block_q: usize,
    pub block_t: usize,
    pub threads: usize,
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig { block_q: 32, block_t: 256, threads: default_threads() }
    }
}

impl TileConfig {
    /// Serial configuration (deterministic single-thread runs / baselines).
    pub fn serial() -> Self {
        TileConfig { threads: 1, ..TileConfig::default() }
    }

    fn checked(&self) -> TileConfig {
        TileConfig {
            block_q: self.block_q.max(1),
            block_t: self.block_t.max(1),
            threads: self.threads.max(1),
        }
    }
}

/// Default worker count: the machine's parallelism, capped so engine
/// workers stacking their own kernel threads cannot oversubscribe wildly.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Column-major copy of a row-major [n, d] buffer: `xt[k*n + i] = x[i*d + k]`.
/// Gives the tile GEMM unit-stride access over train rows.
fn transpose(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut xt = vec![0.0f32; n * d];
    for i in 0..n {
        for k in 0..d {
            xt[k * n + i] = x[i * d + k];
        }
    }
    xt
}

/// f64 squared row norms of a row-major [n, d] buffer (the exact half of
/// the matmul identity — f32 squares are exact in f64).
fn sq_norms(x: &[f32], n: usize, d: usize) -> Vec<f64> {
    (0..n)
        .map(|i| {
            x[i * d..(i + 1) * d]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum()
        })
        .collect()
}

/// Fill `dots[q*bt + t]` with `y_{q0+q} · x_{t0+t}` for a
/// `(q0, bq) × (t0, bt)` tile.
///
/// Loop order k → q → t keeps the transposed train column resident across
/// all `bq` query rows and makes the innermost loop a unit-stride FMA the
/// compiler can vectorize — this is the micro-GEMM at the heart of the
/// reordering.
#[inline]
fn dot_tile(
    y: &[f32],
    xt: &[f32],
    n: usize,
    d: usize,
    (q0, bq): (usize, usize),
    (t0, bt): (usize, usize),
    dots: &mut [f32],
) {
    dots[..bq * bt].fill(0.0);
    for k in 0..d {
        let col = &xt[k * n + t0..k * n + t0 + bt];
        for q in 0..bq {
            let yk = y[(q0 + q) * d + k];
            let row = &mut dots[q * bt..q * bt + bt];
            for (dst, &xv) in row.iter_mut().zip(col) {
                *dst += yk * xv;
            }
        }
    }
}

/// Minimum (query, train) pairs per worker thread: below this, spawn+join
/// overhead (tens of µs per thread) outweighs the compute, so small
/// requests — the serving hot path for padded 32-row buckets — run
/// serially.  Thread count never changes results (each query row's
/// arithmetic is independent of the partition).
const MIN_PAIRS_PER_THREAD: usize = 32 * 1024;

/// Split `rows` query rows (each `width` output values wide) across up to
/// `threads` scoped threads — scaled down so every thread gets at least
/// [`MIN_PAIRS_PER_THREAD`] of the `pairs` total — handing every thread a
/// contiguous `(q_start, q_end, out_chunk)` span.
fn par_query_rows<F>(
    out: &mut [f64],
    rows: usize,
    width: usize,
    pairs: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, usize, &mut [f64]) + Sync,
{
    let threads = threads
        .max(1)
        .min(rows.max(1))
        .min((pairs / MIN_PAIRS_PER_THREAD).max(1));
    if threads <= 1 {
        f(0, rows, out);
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [f64] = out;
        let mut q0 = 0usize;
        let f = &f;
        while q0 < rows {
            let q1 = (q0 + per).min(rows);
            // Detach the span from `rest` so it can cross into the thread
            // while the tail keeps being split.
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut((q1 - q0) * width);
            rest = tail;
            scope.spawn(move || f(q0, q1, chunk));
            q0 = q1;
        }
    });
}

/// Shared precomputation for one (x, y) problem.
struct Prepared {
    xt: Vec<f32>,
    sq_x: Vec<f64>,
    sq_y: Vec<f64>,
    wf: Vec<f64>,
    n: usize,
    m: usize,
}

fn prepare(x: &[f32], w: &[f32], y: &[f32], d: usize) -> Prepared {
    assert!(d >= 1, "dimension must be >= 1");
    let n = w.len();
    assert_eq!(x.len(), n * d, "x must be [n, d] row-major");
    assert_eq!(y.len() % d, 0, "y must be [m, d] row-major");
    let m = y.len() / d;
    Prepared {
        xt: transpose(x, n, d),
        sq_x: sq_norms(x, n, d),
        sq_y: sq_norms(y, m, d),
        wf: w.iter().map(|&v| v as f64).collect(),
        n,
        m,
    }
}

/// Weighted Gaussian KDE via the matmul identity.  Same contract as
/// [`super::native::kde`]: x [n, d], w [n], y [m, d] row-major, returns
/// [m] f64 densities.
pub fn kde(x: &[f32], w: &[f32], y: &[f32], d: usize, h: f64, cfg: &TileConfig) -> Vec<f64> {
    density(x, w, y, d, h, false, cfg)
}

/// Laplace-corrected KDE (signed).  Mirrors [`super::native::laplace`].
pub fn laplace(x: &[f32], w: &[f32], y: &[f32], d: usize, h: f64, cfg: &TileConfig) -> Vec<f64> {
    density(x, w, y, d, h, true, cfg)
}

fn density(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    d: usize,
    h: f64,
    laplace_term: bool,
    cfg: &TileConfig,
) -> Vec<f64> {
    let cfg = cfg.checked();
    let p = prepare(x, w, y, d);
    let count: f64 = p.wf.iter().sum();
    assert!(count > 0.0, "no effective samples");
    let norm = normalizer(h, d) / count;
    let inv2h2 = 1.0 / (2.0 * h * h);
    let half_d = d as f64 / 2.0;

    let mut out = vec![0.0f64; p.m];
    par_query_rows(&mut out, p.m, 1, p.m * p.n, cfg.threads, |qa, qb, chunk| {
        let mut dots = vec![0.0f32; cfg.block_q * cfg.block_t];
        let mut q0 = qa;
        while q0 < qb {
            let bq = cfg.block_q.min(qb - q0);
            let mut acc = vec![0.0f64; bq];
            let mut t0 = 0usize;
            while t0 < p.n {
                let bt = cfg.block_t.min(p.n - t0);
                dot_tile(y, &p.xt, p.n, d, (q0, bq), (t0, bt), &mut dots);
                for q in 0..bq {
                    let sq_y = p.sq_y[q0 + q];
                    let mut a = 0.0f64;
                    for t in 0..bt {
                        let wi = p.wf[t0 + t];
                        if wi == 0.0 {
                            continue;
                        }
                        let d2 = (sq_y + p.sq_x[t0 + t]
                            - 2.0 * dots[q * bt + t] as f64)
                            .max(0.0);
                        let scaled = d2 * inv2h2;
                        let e = (-scaled).exp();
                        a += if laplace_term {
                            wi * e * (1.0 + half_d - scaled)
                        } else {
                            wi * e
                        };
                    }
                    acc[q] += a;
                }
                t0 += bt;
            }
            for q in 0..bq {
                chunk[q0 + q - qa] = acc[q] * norm;
            }
            q0 += bq;
        }
    });
    out
}

/// Score of the weighted KDE of `x` at query rows `y` — the flash twin of
/// [`super::native::score_at`] (and, with `y = x`, of
/// [`super::native::score`]): returns [m, d] row-major f64, `1e-30`
/// denominator guard.
pub fn score_at(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    d: usize,
    h_s: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    let cfg = cfg.checked();
    let p = prepare(x, w, y, d);
    let inv2h2 = 1.0 / (2.0 * h_s * h_s);

    let mut out = vec![0.0f64; p.m * d];
    par_query_rows(&mut out, p.m, d, p.m * p.n, cfg.threads, |qa, qb, chunk| {
        let mut dots = vec![0.0f32; cfg.block_q * cfg.block_t];
        let mut q0 = qa;
        while q0 < qb {
            let bq = cfg.block_q.min(qb - q0);
            let mut denom = vec![0.0f64; bq];
            let mut numer = vec![0.0f64; bq * d];
            let mut t0 = 0usize;
            while t0 < p.n {
                let bt = cfg.block_t.min(p.n - t0);
                dot_tile(y, &p.xt, p.n, d, (q0, bq), (t0, bt), &mut dots);
                for q in 0..bq {
                    let sq_y = p.sq_y[q0 + q];
                    let numer_q = &mut numer[q * d..(q + 1) * d];
                    for t in 0..bt {
                        let wi = p.wf[t0 + t];
                        if wi == 0.0 {
                            continue;
                        }
                        let d2 = (sq_y + p.sq_x[t0 + t]
                            - 2.0 * dots[q * bt + t] as f64)
                            .max(0.0);
                        let phi = wi * (-d2 * inv2h2).exp();
                        denom[q] += phi;
                        let xi = &x[(t0 + t) * d..(t0 + t + 1) * d];
                        for (acc, &v) in numer_q.iter_mut().zip(xi) {
                            *acc += phi * v as f64;
                        }
                    }
                }
                t0 += bt;
            }
            for q in 0..bq {
                let dq = denom[q].max(1e-30);
                let yq = &y[(q0 + q) * d..(q0 + q + 1) * d];
                for k in 0..d {
                    chunk[(q0 + q - qa) * d + k] =
                        (numer[q * d + k] - yq[k] as f64 * dq) / (h_s * h_s * dq);
                }
            }
            q0 += bq;
        }
    });
    out
}

/// Debiased samples X^SD = X + (h²/2)·s(X); masked rows pass through.
/// Mirrors [`super::native::debias`] (f32 output, the artifact wire format).
pub fn debias(x: &[f32], w: &[f32], d: usize, h: f64, h_s: f64, cfg: &TileConfig) -> Vec<f32> {
    let n = w.len();
    let s = score_at(x, w, x, d, h_s, cfg);
    let shift = 0.5 * h * h;
    let mut out = x.to_vec();
    for i in 0..n {
        if w[i] == 0.0 {
            continue;
        }
        for k in 0..d {
            out[i * d + k] = (x[i * d + k] as f64 + shift * s[i * d + k]) as f32;
        }
    }
    out
}

/// Full SD-KDE: debias then evaluate.  Mirrors [`super::native::sdkde`].
pub fn sdkde(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    d: usize,
    h: f64,
    h_s: f64,
    cfg: &TileConfig,
) -> Vec<f64> {
    let x_sd = debias(x, w, d, h, h_s, cfg);
    kde(&x_sd, w, y, d, h, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::native;
    use crate::util::rng::Pcg64;

    fn sample(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Pcg64::seeded(seed).normal_vec_f32(n * d)
    }

    fn assert_close(a: &[f64], b: &[f64], rtol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let rel = (x - y).abs() / y.abs().max(1e-30);
            assert!(rel < rtol, "row {i}: {x} vs {y} (rel {rel:.2e})");
        }
    }

    #[test]
    fn kde_matches_oracle_small() {
        let (n, m, d) = (97, 23, 3);
        let x = sample(n, d, 1);
        let y = sample(m, d, 2);
        let w = vec![1.0f32; n];
        let got = kde(&x, &w, &y, d, 0.6, &TileConfig::default());
        let want = native::kde(&x, &w, &y, d, 0.6);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn single_point_closed_form() {
        let x = vec![0.0f32, 0.0];
        let w = vec![1.0f32];
        let y = vec![0.3f32, -0.4];
        let h = 0.7;
        let got = kde(&x, &w, &y, 2, h, &TileConfig::serial())[0];
        let tau = std::f64::consts::TAU;
        let want = (-0.25 / (2.0 * h * h)).exp() / (tau * h * h);
        assert!((got - want).abs() < 1e-7, "{got} vs {want}");
    }

    #[test]
    fn tiles_smaller_than_problem_still_cover_all_pairs() {
        let (n, m, d) = (53, 17, 1);
        let x = sample(n, d, 3);
        let y = sample(m, d, 4);
        let w = vec![1.0f32; n];
        let tiny = TileConfig { block_q: 2, block_t: 3, threads: 1 };
        let got = kde(&x, &w, &y, d, 0.4, &tiny);
        let want = native::kde(&x, &w, &y, d, 0.4);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn score_at_matches_oracle() {
        let (n, m, d) = (64, 9, 2);
        let x = sample(n, d, 5);
        let y = sample(m, d, 6);
        let w = vec![1.0f32; n];
        let got = score_at(&x, &w, &y, d, 0.5, &TileConfig::default());
        let want = native::score_at(&x, &w, &y, d, 0.5);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn transpose_round_trip() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let xt = transpose(&x, 3, 2);
        assert_eq!(xt, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn threads_do_not_change_results() {
        // Big enough that MIN_PAIRS_PER_THREAD actually admits 4 workers.
        let (n, m, d) = (600, 256, 4);
        assert!(n * m / MIN_PAIRS_PER_THREAD >= 4);
        let x = sample(n, d, 7);
        let y = sample(m, d, 8);
        let w = vec![1.0f32; n];
        let serial = kde(&x, &w, &y, d, 0.7, &TileConfig::serial());
        let threaded =
            kde(&x, &w, &y, d, 0.7, &TileConfig { threads: 4, ..TileConfig::default() });
        // Thread partitioning only splits query rows: bit-identical.
        assert_eq!(serial, threaded);
    }

    #[test]
    fn small_problems_run_serially_but_correctly() {
        // Below the pairs floor the kernel must not spawn (latency), and
        // results are the same either way.
        let (n, m, d) = (40, 8, 2);
        let x = sample(n, d, 9);
        let y = sample(m, d, 10);
        let w = vec![1.0f32; n];
        let a = kde(&x, &w, &y, d, 0.5, &TileConfig { threads: 16, ..TileConfig::default() });
        let b = kde(&x, &w, &y, d, 0.5, &TileConfig::serial());
        assert_eq!(a, b);
    }
}
