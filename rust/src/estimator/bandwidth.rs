//! Bandwidth selection rules.
//!
//! * `silverman` — the classical rule of thumb the paper's KDE baseline is
//!   tuned with: h = sigma * (4 / (n (d + 2)))^{1/(d+4)}.
//! * `sdkde_rate` — the SD-KDE-optimal scaling h ∝ n^{-1/(d+8)} (the
//!   improved AMISE exponent O(n^{-8/(d+8)}) comes from this schedule).
//! * `score_bandwidth` — the heat-semigroup score bandwidth t' = t/2, i.e.
//!   h_s = h / sqrt(2) (paper §5).

/// Pooled standard deviation across dimensions (the isotropic-kernel scale).
pub fn pooled_std(x: &[f32], n: usize, d: usize) -> f64 {
    assert_eq!(x.len(), n * d, "x must be [n, d] row-major");
    assert!(n > 1, "need at least two samples");
    let mut total_var = 0.0f64;
    for j in 0..d {
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for i in 0..n {
            let v = x[i * d + j] as f64;
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        total_var += (sum2 / n as f64 - mean * mean).max(0.0);
    }
    (total_var / d as f64).sqrt()
}

/// Silverman's rule of thumb in d dimensions.
pub fn silverman(x: &[f32], n: usize, d: usize) -> f64 {
    let sigma = pooled_std(x, n, d);
    let factor = (4.0 / ((d as f64 + 2.0) * n as f64)).powf(1.0 / (d as f64 + 4.0));
    sigma * factor
}

/// SD-KDE-rate bandwidth: same plug-in scale, improved exponent.
pub fn sdkde_rate(x: &[f32], n: usize, d: usize) -> f64 {
    let sigma = pooled_std(x, n, d);
    let factor = (4.0 / ((d as f64 + 2.0) * n as f64)).powf(1.0 / (d as f64 + 8.0));
    sigma * factor
}

/// Score-estimation bandwidth t' = t/2 => h_s = h / sqrt(2).
pub fn score_bandwidth(h: f64) -> f64 {
    h / std::f64::consts::SQRT_2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn gaussian_sample(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        rng.normal_vec_f32(n * d)
    }

    #[test]
    fn pooled_std_of_standard_normal_is_one() {
        let n = 20_000;
        let x = gaussian_sample(n, 3, 1);
        let s = pooled_std(&x, n, 3);
        assert!((s - 1.0).abs() < 0.03, "s={s}");
    }

    #[test]
    fn silverman_shrinks_with_n() {
        let x = gaussian_sample(4096, 1, 2);
        let h_small = silverman(&x[..512], 512, 1);
        let h_big = silverman(&x, 4096, 1);
        assert!(h_big < h_small);
        // 1-D Silverman on a standard normal ~ 1.06 n^{-1/5}: sanity band.
        let expect = (4.0 / 3.0f64).powf(0.2) * (4096f64).powf(-0.2);
        assert!((h_big - expect).abs() / expect < 0.1, "h={h_big} expect~{expect}");
    }

    #[test]
    fn sdkde_rate_decays_slower_than_silverman() {
        // n^{-1/(d+8)} decays slower than n^{-1/(d+4)}: for large n the
        // SD-KDE bandwidth is *larger* (it can afford more smoothing).
        let x = gaussian_sample(8192, 1, 3);
        let h_silverman = silverman(&x, 8192, 1);
        let h_sd = sdkde_rate(&x, 8192, 1);
        assert!(h_sd > h_silverman);
    }

    #[test]
    fn score_bandwidth_halves_t() {
        let h = 0.8;
        let hs = score_bandwidth(h);
        assert!((hs * hs - h * h / 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "at least two samples")]
    fn rejects_single_sample() {
        pooled_std(&[1.0], 1, 1);
    }
}
