//! Estimator layer: shared estimator/variant vocabulary, bandwidth rules,
//! the native Rust scalar baselines/oracles, and the tiled flash kernels
//! backing the native execution backend.

pub mod bandwidth;
pub mod flash;
pub mod native;

use std::fmt;

/// Which density estimator a request/bench asks for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EstimatorKind {
    /// Vanilla Gaussian KDE.
    Kde,
    /// Score-debiased KDE (fit = score+shift, eval = KDE on debiased set).
    SdKde,
    /// Laplace-corrected KDE (signed, no score pass).
    Laplace,
}

impl EstimatorKind {
    /// Parse a CLI/wire spelling (`kde`, `sdkde`/`sd-kde`, `laplace`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "kde" => Some(Self::Kde),
            "sdkde" | "sd-kde" | "sd_kde" => Some(Self::SdKde),
            "laplace" | "laplace-kde" | "flash-laplace" => Some(Self::Laplace),
            _ => None,
        }
    }

    /// Canonical spelling (what `parse` round-trips).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Kde => "kde",
            Self::SdKde => "sdkde",
            Self::Laplace => "laplace",
        }
    }

    /// The artifact pipeline evaluating a *fitted* model of this kind.
    /// SD-KDE evaluates a plain KDE over debiased samples.
    pub fn eval_pipeline(&self) -> &'static str {
        match self {
            Self::Kde | Self::SdKde => "kde",
            Self::Laplace => "laplace",
        }
    }

    /// Whether fitting requires the score pass.
    pub fn needs_fit(&self) -> bool {
        matches!(self, Self::SdKde)
    }

    /// Every estimator kind (grid sweeps, protocol fuzzing).
    pub const ALL: [EstimatorKind; 3] = [Self::Kde, Self::SdKde, Self::Laplace];
}

impl fmt::Display for EstimatorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Execution variant (maps 1:1 to artifact variants; DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Pallas streaming tiles (the paper's contribution).
    Flash,
    /// Materializing GEMM baseline ("SD-KDE (Torch)").
    Gemm,
    /// Row-block streaming baseline (PyKeOps analogue).
    Stream,
    /// Broadcasted elementwise baseline (small shapes only).
    Naive,
    /// Non-fused Laplace (second pass recomputes distances); only valid
    /// for the laplace pipeline.
    NonFused,
}

impl Variant {
    /// Parse a config/wire spelling (`flash`, `gemm`, `stream`, `naive`,
    /// `nonfused`/`non-fused`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "flash" => Some(Self::Flash),
            "gemm" => Some(Self::Gemm),
            "stream" => Some(Self::Stream),
            "naive" => Some(Self::Naive),
            "nonfused" | "non-fused" => Some(Self::NonFused),
            _ => None,
        }
    }

    /// Canonical spelling (artifact-manifest variant id).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Flash => "flash",
            Self::Gemm => "gemm",
            Self::Stream => "stream",
            Self::Naive => "naive",
            Self::NonFused => "nonfused",
        }
    }

    /// Every variant (grid sweeps, protocol fuzzing).
    pub const ALL: [Variant; 5] =
        [Self::Flash, Self::Gemm, Self::Stream, Self::Naive, Self::NonFused];
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_round_trip() {
        for k in [EstimatorKind::Kde, EstimatorKind::SdKde, EstimatorKind::Laplace] {
            assert_eq!(EstimatorKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(EstimatorKind::parse("SD-KDE"), Some(EstimatorKind::SdKde));
        assert_eq!(EstimatorKind::parse("bogus"), None);
    }

    #[test]
    fn variant_parse_round_trip() {
        for v in [Variant::Flash, Variant::Gemm, Variant::Stream,
                  Variant::Naive, Variant::NonFused] {
            assert_eq!(Variant::parse(v.as_str()), Some(v));
        }
        assert_eq!(Variant::parse("non-fused"), Some(Variant::NonFused));
        assert_eq!(Variant::parse("turbo"), None);
    }

    #[test]
    fn eval_pipeline_mapping() {
        assert_eq!(EstimatorKind::Kde.eval_pipeline(), "kde");
        assert_eq!(EstimatorKind::SdKde.eval_pipeline(), "kde");
        assert_eq!(EstimatorKind::Laplace.eval_pipeline(), "laplace");
        assert!(EstimatorKind::SdKde.needs_fit());
        assert!(!EstimatorKind::Kde.needs_fit());
    }
}
