//! Maximum mean discrepancy: a two-sample kernel statistic from three
//! kernel sums (DESIGN.md §17).
//!
//! The biased V-statistic at bandwidth `h`:
//!
//! ```text
//! MMD²(X, Y) = S_XX/n² + S_YY/m² − 2·S_XY/(n·m)
//!   S_AB = Σ_i Σ_j exp(−‖a_i − b_j‖²/(2h²))
//! ```
//!
//! Each `S` is one MatVec sweep with an all-ones vector, summed — so the
//! statistic inherits the flash path's tiling and determinism.  The
//! Gaussian kernel is characteristic, so MMD² ≥ 0 with equality iff the
//! empirical measures coincide; fp round-off can land a same-sample pair
//! a hair below zero, which [`mmd_from_sums`] clamps.

use anyhow::{bail, Result};

use crate::estimator::flash::{self, PreparedTrain, TileConfig};

/// A computed MMD statistic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MmdResult {
    /// Squared statistic (biased V-estimate), clamped at 0.
    pub mmd2: f64,
    /// `sqrt(mmd2)` — the distance on the RKHS mean embeddings.
    pub mmd: f64,
    /// Rows in the first sample.
    pub n: usize,
    /// Rows in the second sample.
    pub m: usize,
}

/// Combine the three kernel sums into the biased V-statistic.  Split out
/// so the serving path (`Coordinator::mmd`, which computes its sums
/// through MatVec queries) and the local path share one formula.
pub fn mmd_from_sums(s_xx: f64, s_xy: f64, s_yy: f64, n: usize, m: usize) -> MmdResult {
    let (nf, mf) = (n as f64, m as f64);
    let mmd2 = (s_xx / (nf * nf) + s_yy / (mf * mf) - 2.0 * s_xy / (nf * mf)).max(0.0);
    MmdResult { mmd2, mmd: mmd2.sqrt(), n, m }
}

/// MMD between two row-major samples `x: [n, d]` and `y: [m, d]` under
/// the Gaussian kernel at bandwidth `h`.
pub fn mmd(x: &[f32], y: &[f32], d: usize, h: f64, cfg: &TileConfig) -> Result<MmdResult> {
    if d == 0 || x.is_empty() || x.len() % d != 0 {
        bail!("x must be a non-empty [n, {d}] row-major buffer");
    }
    if y.is_empty() || y.len() % d != 0 {
        bail!("y must be a non-empty [m, {d}] row-major buffer");
    }
    if !(h > 0.0) {
        bail!("bandwidth must be positive (got {h})");
    }
    let n = x.len() / d;
    let m = y.len() / d;
    let cfg = cfg.checked();
    let ones_n = vec![1.0f32; n];
    let ones_m = vec![1.0f32; m];
    // The X-side prepared train serves both the XX and the XY sums.
    let train_x = PreparedTrain::new(x, &ones_n, d);
    let s_xx: f64 = flash::matvec_prepared(&train_x, &ones_n, x, h, &cfg)
        .iter()
        .sum();
    let s_xy: f64 = flash::matvec_prepared(&train_x, &ones_n, y, h, &cfg)
        .iter()
        .sum();
    let s_yy: f64 = flash::matvec(y, &ones_m, &ones_m, y, d, h, &cfg)
        .iter()
        .sum();
    Ok(mmd_from_sums(s_xx, s_xy, s_yy, n, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Pcg64::seeded(seed).normal_vec_f32(n * d)
    }

    #[test]
    fn identical_samples_give_zero() {
        let x = sample(80, 3, 5);
        let res = mmd(&x, &x, 3, 0.7, &TileConfig::default()).unwrap();
        // S_XX = S_XY = S_YY exactly, so the combination cancels to fp
        // noise and the clamp pins it at 0 — but never negative.
        assert!(res.mmd2 >= 0.0);
        assert!(res.mmd2 < 1e-9, "mmd2 = {}", res.mmd2);
    }

    #[test]
    fn matches_dense_oracle() {
        let (n, m, d, h) = (37, 23, 2, 0.9);
        let x = sample(n, d, 10);
        let y = sample(m, d, 11);
        let res = mmd(&x, &y, d, h, &TileConfig::default()).unwrap();
        let k = |a: &[f32], b: &[f32]| -> f64 {
            let sq: f64 = a
                .iter()
                .zip(b)
                .map(|(&p, &q)| (p as f64 - q as f64) * (p as f64 - q as f64))
                .sum();
            (-sq / (2.0 * h * h)).exp()
        };
        let mut s_xx = 0.0;
        let mut s_xy = 0.0;
        let mut s_yy = 0.0;
        for i in 0..n {
            for j in 0..n {
                s_xx += k(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]);
            }
            for j in 0..m {
                s_xy += k(&x[i * d..(i + 1) * d], &y[j * d..(j + 1) * d]);
            }
        }
        for i in 0..m {
            for j in 0..m {
                s_yy += k(&y[i * d..(i + 1) * d], &y[j * d..(j + 1) * d]);
            }
        }
        let oracle = mmd_from_sums(s_xx, s_xy, s_yy, n, m);
        let rel = (res.mmd2 - oracle.mmd2).abs() / oracle.mmd2.max(1e-12);
        assert!(rel < 1e-4, "mmd2 {} vs oracle {}", res.mmd2, oracle.mmd2);
    }

    #[test]
    fn shifted_distribution_scores_higher_than_fresh_draw() {
        let (n, d, h) = (100, 2, 0.8);
        let x = sample(n, d, 21);
        let fresh = sample(n, d, 22);
        let shifted: Vec<f32> = sample(n, d, 23).iter().map(|&v| v + 3.0).collect();
        let cfg = TileConfig::default();
        let near = mmd(&x, &fresh, d, h, &cfg).unwrap();
        let far = mmd(&x, &shifted, d, h, &cfg).unwrap();
        assert!(far.mmd2 > 0.1, "shifted mmd2 = {}", far.mmd2);
        assert!(
            far.mmd2 > 10.0 * near.mmd2,
            "far {} vs near {}",
            far.mmd2,
            near.mmd2
        );
    }

    #[test]
    fn deterministic_and_symmetric_in_its_arguments() {
        let x = sample(40, 3, 30);
        let y = sample(55, 3, 31);
        let cfg = TileConfig::default();
        let a = mmd(&x, &y, 3, 0.6, &cfg).unwrap();
        let b = mmd(&x, &y, 3, 0.6, &cfg).unwrap();
        assert_eq!(a.mmd2.to_bits(), b.mmd2.to_bits());
        // MMD(X, Y) == MMD(Y, X) up to fp re-association of the sums.
        let c = mmd(&y, &x, 3, 0.6, &cfg).unwrap();
        let rel = (a.mmd2 - c.mmd2).abs() / a.mmd2.max(1e-12);
        assert!(rel < 1e-10, "{} vs {}", a.mmd2, c.mmd2);
    }

    #[test]
    fn degenerate_inputs_are_typed_errors() {
        let x = sample(4, 2, 1);
        assert!(mmd(&x, &x, 0, 0.5, &TileConfig::default()).is_err());
        assert!(mmd(&[], &x, 2, 0.5, &TileConfig::default()).is_err());
        assert!(mmd(&x, &x[..3], 2, 0.5, &TileConfig::default()).is_err());
        assert!(mmd(&x, &x, 2, 0.0, &TileConfig::default()).is_err());
    }
}
