//! Kernel PCA: top eigenpair of the centered kernel matrix by power
//! iteration (DESIGN.md §17).
//!
//! The centered kernel matrix is `K̃ = H K H` with `H = I − 11ᵀ/|A|`
//! taken over the *active* (unmasked) rows `A`; masked rows (`w == 0`)
//! never contribute — their column is zeroed by the weight inside the
//! MatVec, their row is zeroed here, and their component entry is pinned
//! to 0.  The iterate stays centered over `A`, so `K̃u` reduces to one
//! MatVec sweep plus one re-centering: `H K H u = H (K u)` when
//! `H u = u`.
//!
//! Determinism: the start vector comes from a [`SplitMix64`] stream
//! seeded by [`PcaOpts::seed`], each draw keyed by row index — equal
//! seeds give bitwise-equal trajectories, and the MatVec sweeps inherit
//! the flash path's block-shape/thread-count inertness.

use anyhow::{bail, Result};

use crate::estimator::flash::{self, PreparedTrain, TileConfig};
use crate::util::rng::SplitMix64;

/// Power-iteration knobs.  All defaults are deterministic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PcaOpts {
    /// Sweep cap; iteration stops here even without convergence (the
    /// result reports `converged: false`).
    pub max_iters: usize,
    /// Relative eigenvalue-convergence tolerance:
    /// `|λ_t − λ_{t−1}| ≤ tol · max(|λ_t|, 1)` stops the loop.  Sweeps
    /// cross an f32 boundary, so tolerances far below ~1e-6 may never
    /// trigger.
    pub tol: f64,
    /// Seed of the start-vector stream (equal seeds ⇒ bitwise-equal runs).
    pub seed: u64,
}

impl Default for PcaOpts {
    fn default() -> Self {
        PcaOpts { max_iters: 200, tol: 1e-5, seed: 0x5EED }
    }
}

/// Result of a power iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct PcaResult {
    /// Top eigenvalue of the centered kernel matrix (Rayleigh quotient at
    /// the final iterate).
    pub eigenvalue: f64,
    /// Unit top eigenvector, one entry per train row; masked rows are
    /// exactly 0.  The sign is an artifact of the seed — compare
    /// components up to sign.
    pub component: Vec<f32>,
    /// Sweeps executed (each is one MatVec pass over the train rows).
    pub iters: u64,
    /// Whether the eigenvalue tolerance was met before `max_iters`.
    pub converged: bool,
}

/// Power iteration over a caller-supplied MatVec sweep.
///
/// `active[i]` marks live rows; `sweep(v)` must return `K·v` (any
/// convention where masked *columns* contribute 0 — the flash MatVec's
/// `w == 0` does this); masked *rows* of the sweep output are discarded
/// here.  Split out from [`kernel_pca`] so the serving path can drive the
/// identical algorithm through MatVec queries (`Coordinator::kernel_pca`)
/// and count sweeps.
pub fn power_iteration<F>(
    active: &[bool],
    opts: &PcaOpts,
    mut sweep: F,
) -> Result<PcaResult>
where
    F: FnMut(&[f32]) -> Result<Vec<f64>>,
{
    let n = active.len();
    let n_active = active.iter().filter(|&&a| a).count();
    if n_active < 2 {
        bail!("kernel PCA needs at least 2 active rows, got {n_active}");
    }
    if opts.max_iters == 0 {
        bail!("max_iters must be >= 1");
    }
    if !(opts.tol > 0.0) {
        bail!("tol must be positive (got {})", opts.tol);
    }

    // Seeded start: one draw per row index (masked rows draw and discard,
    // so the stream alignment never depends on the mask), centered and
    // normalized over the active set.
    let mut stream = SplitMix64::new(opts.seed);
    let mut u: Vec<f64> = (0..n)
        .map(|i| {
            let draw = stream.uniform() - 0.5;
            if active[i] { draw } else { 0.0 }
        })
        .collect();
    center(&mut u, active, n_active);
    if !normalize(&mut u) {
        // A uniform draw landing every active entry exactly on the mean is
        // measure-zero but cheap to repair deterministically.
        let first = active.iter().position(|&a| a).expect("n_active >= 2");
        let second = active.iter().skip(first + 1).position(|&a| a).expect("n_active >= 2");
        u[first] = 0.5f64.sqrt();
        u[first + 1 + second] = -(0.5f64.sqrt());
    }

    let mut eigenvalue = 0.0f64;
    let mut iters = 0u64;
    let mut converged = false;
    for _ in 0..opts.max_iters {
        iters += 1;
        let v32: Vec<f32> = u.iter().map(|&x| x as f32).collect();
        let mut b = sweep(&v32)?;
        if b.len() != n {
            bail!("sweep returned {} entries for {n} rows", b.len());
        }
        for (bi, &a) in b.iter_mut().zip(active) {
            if !a {
                *bi = 0.0;
            }
        }
        center(&mut b, active, n_active);
        let prev = eigenvalue;
        // Rayleigh quotient: u is unit, so λ = uᵀ K̃ u = uᵀ b.
        eigenvalue = u.iter().zip(&b).map(|(&ui, &bi)| ui * bi).sum();
        if !normalize(&mut b) {
            // K̃ annihilated the iterate: the centered matrix is (numerically)
            // zero on the current subspace.  λ = 0 is the honest answer.
            eigenvalue = 0.0;
            converged = true;
            break;
        }
        u = b;
        if iters > 1 && (eigenvalue - prev).abs() <= opts.tol * eigenvalue.abs().max(1.0) {
            converged = true;
            break;
        }
    }

    Ok(PcaResult {
        eigenvalue,
        component: u.iter().map(|&x| x as f32).collect(),
        iters,
        converged,
    })
}

/// Top eigenpair of the centered kernel matrix of a weighted train set:
/// `x` row-major `[n, d]` with `n = w.len()`, `w == 0.0` masking rows
/// exactly as in the estimators, Gaussian kernel at bandwidth `h`.
pub fn kernel_pca(
    x: &[f32],
    w: &[f32],
    d: usize,
    h: f64,
    cfg: &TileConfig,
    opts: &PcaOpts,
) -> Result<PcaResult> {
    if d == 0 || x.len() != w.len() * d {
        bail!("x must be [n, {d}] row-major with n = w.len()");
    }
    if !(h > 0.0) {
        bail!("bandwidth must be positive (got {h})");
    }
    let active: Vec<bool> = w.iter().map(|&wi| wi != 0.0).collect();
    let train = PreparedTrain::new(x, w, d);
    let cfg = cfg.checked();
    power_iteration(&active, opts, |v| {
        Ok(flash::matvec_prepared(&train, v, x, h, &cfg))
    })
}

/// Subtract the active-set mean from the active entries (masked entries
/// are untouched — they are kept at exactly 0 by the callers).
fn center(v: &mut [f64], active: &[bool], n_active: usize) {
    let mean: f64 = v
        .iter()
        .zip(active)
        .filter(|&(_, &a)| a)
        .map(|(&x, _)| x)
        .sum::<f64>()
        / n_active as f64;
    for (x, &a) in v.iter_mut().zip(active) {
        if a {
            *x -= mean;
        }
    }
}

/// Scale to unit 2-norm; returns false (leaving `v` untouched) when the
/// norm is exactly 0.
fn normalize(v: &mut [f64]) -> bool {
    let norm = v.iter().map(|&x| x * x).sum::<f64>().sqrt();
    if norm == 0.0 {
        return false;
    }
    for x in v.iter_mut() {
        *x /= norm;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Pcg64::seeded(seed).normal_vec_f32(n * d)
    }

    /// Dense centered kernel matrix over the active rows (f64 oracle).
    fn dense_centered_k(x: &[f32], w: &[f32], d: usize, h: f64) -> Vec<f64> {
        let n = w.len();
        let inv = 1.0 / (2.0 * h * h);
        let mut k = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                if w[i] == 0.0 || w[j] == 0.0 {
                    continue;
                }
                let mut sq = 0.0f64;
                for t in 0..d {
                    let diff = x[i * d + t] as f64 - x[j * d + t] as f64;
                    sq += diff * diff;
                }
                k[i * n + j] = w[j] as f64 * (-sq * inv).exp();
            }
        }
        // H K H over the active set.
        let active: Vec<usize> =
            (0..n).filter(|&i| w[i] != 0.0).collect();
        let na = active.len() as f64;
        let row_means: Vec<f64> = (0..n)
            .map(|i| active.iter().map(|&j| k[i * n + j]).sum::<f64>() / na)
            .collect();
        let col_means: Vec<f64> = (0..n)
            .map(|j| active.iter().map(|&i| k[i * n + j]).sum::<f64>() / na)
            .collect();
        let grand: f64 = active.iter().map(|&i| row_means[i]).sum::<f64>() / na;
        for &i in &active {
            for &j in &active {
                k[i * n + j] += grand - row_means[i] - col_means[j];
            }
        }
        k
    }

    /// f64 power iteration on a dense matrix — the conformance oracle.
    fn dense_top_eigenpair(k: &[f64], n: usize, iters: usize) -> (f64, Vec<f64>) {
        let mut u: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();
        let norm = u.iter().map(|&x| x * x).sum::<f64>().sqrt();
        u.iter_mut().for_each(|x| *x /= norm);
        let mut lambda = 0.0;
        for _ in 0..iters {
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| k[i * n + j] * u[j]).sum())
                .collect();
            lambda = u.iter().zip(&b).map(|(&a, &c)| a * c).sum();
            let norm = b.iter().map(|&x| x * x).sum::<f64>().sqrt();
            if norm == 0.0 {
                return (0.0, u);
            }
            u = b.iter().map(|&x| x / norm).collect();
        }
        (lambda, u)
    }

    #[test]
    fn power_iteration_recovers_planted_top_eigenpair() {
        // M = 5 q₁q₁ᵀ + 1 q₂q₂ᵀ on orthonormal q₁, q₂ — the sweep is a
        // dense multiply, so this pins the iteration logic in isolation.
        let n = 24;
        let mut rng = Pcg64::seeded(31);
        let mut q1: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // Center q1 so it lives in the subspace the iteration preserves
        // (the algorithm re-centers every sweep output).
        let mean = q1.iter().sum::<f64>() / n as f64;
        q1.iter_mut().for_each(|x| *x -= mean);
        let norm = q1.iter().map(|&x| x * x).sum::<f64>().sqrt();
        q1.iter_mut().for_each(|x| *x /= norm);
        let mut q2: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = q2.iter().sum::<f64>() / n as f64;
        q2.iter_mut().for_each(|x| *x -= mean);
        let dot = q1.iter().zip(&q2).map(|(&a, &b)| a * b).sum::<f64>();
        q2.iter_mut().zip(&q1).for_each(|(x, &q)| *x -= dot * q);
        let norm = q2.iter().map(|&x| x * x).sum::<f64>().sqrt();
        q2.iter_mut().for_each(|x| *x /= norm);

        let m: Vec<f64> = (0..n * n)
            .map(|idx| {
                let (i, j) = (idx / n, idx % n);
                5.0 * q1[i] * q1[j] + 1.0 * q2[i] * q2[j]
            })
            .collect();
        let active = vec![true; n];
        let res = power_iteration(&active, &PcaOpts::default(), |v| {
            Ok((0..n)
                .map(|i| (0..n).map(|j| m[i * n + j] * v[j] as f64).sum())
                .collect())
        })
        .unwrap();
        assert!(res.converged, "did not converge in {} iters", res.iters);
        assert!(
            (res.eigenvalue - 5.0).abs() < 1e-3,
            "eigenvalue {} != 5",
            res.eigenvalue
        );
        let cos: f64 = res
            .component
            .iter()
            .zip(&q1)
            .map(|(&c, &q)| c as f64 * q)
            .sum();
        assert!(cos.abs() > 0.999, "|cos(component, q1)| = {}", cos.abs());
    }

    #[test]
    fn kernel_pca_matches_dense_oracle() {
        let (n, d, h) = (90, 3, 0.8);
        let x = sample(n, d, 101);
        let mut w = vec![1.0f32; n];
        w[7] = 0.0;
        w[40] = 0.0;
        let res = kernel_pca(&x, &w, d, h, &TileConfig::default(), &PcaOpts::default())
            .unwrap();
        assert!(res.converged);
        let k = dense_centered_k(&x, &w, d, h);
        let (lambda, vec) = dense_top_eigenpair(&k, n, 2000);
        let rel = (res.eigenvalue - lambda).abs() / lambda.abs().max(1.0);
        assert!(rel < 1e-3, "eigenvalue {} vs oracle {lambda}", res.eigenvalue);
        let cos: f64 = res
            .component
            .iter()
            .zip(&vec)
            .map(|(&c, &v)| c as f64 * v)
            .sum();
        assert!(cos.abs() > 0.999, "|cos| = {}", cos.abs());
        // Masked rows are pinned to exactly 0 in the component.
        assert_eq!(res.component[7], 0.0);
        assert_eq!(res.component[40], 0.0);
    }

    #[test]
    fn kernel_pca_is_seed_deterministic_and_seed_insensitive_in_value() {
        let (n, d, h) = (60, 2, 0.7);
        let x = sample(n, d, 55);
        let w = vec![1.0f32; n];
        let cfg = TileConfig::default();
        let a = kernel_pca(&x, &w, d, h, &cfg, &PcaOpts::default()).unwrap();
        let b = kernel_pca(&x, &w, d, h, &cfg, &PcaOpts::default()).unwrap();
        assert_eq!(a.eigenvalue.to_bits(), b.eigenvalue.to_bits());
        assert_eq!(a.component, b.component);
        assert_eq!(a.iters, b.iters);
        // A different seed converges to the same eigenvalue (sign of the
        // component may flip).
        let c = kernel_pca(&x, &w, d, h, &cfg, &PcaOpts { seed: 999, ..PcaOpts::default() })
            .unwrap();
        let rel = (a.eigenvalue - c.eigenvalue).abs() / a.eigenvalue.abs().max(1.0);
        assert!(rel < 1e-4, "{} vs {}", a.eigenvalue, c.eigenvalue);
    }

    #[test]
    fn kernel_pca_masked_rows_match_compacted_subset() {
        let (n, d, h) = (50, 3, 0.9);
        let x = sample(n, d, 77);
        let mut w = vec![1.0f32; n];
        for i in [3usize, 11, 29, 48] {
            w[i] = 0.0;
        }
        let masked =
            kernel_pca(&x, &w, d, h, &TileConfig::default(), &PcaOpts::default()).unwrap();
        // Physically drop the masked rows: the active submatrix is
        // identical, so the eigenvalue must agree to fp noise.
        let mut xs = Vec::new();
        for i in 0..n {
            if w[i] != 0.0 {
                xs.extend_from_slice(&x[i * d..(i + 1) * d]);
            }
        }
        let ws = vec![1.0f32; n - 4];
        let compact =
            kernel_pca(&xs, &ws, d, h, &TileConfig::default(), &PcaOpts::default()).unwrap();
        let rel = (masked.eigenvalue - compact.eigenvalue).abs()
            / compact.eigenvalue.abs().max(1.0);
        assert!(rel < 1e-5, "{} vs {}", masked.eigenvalue, compact.eigenvalue);
    }

    #[test]
    fn degenerate_inputs_are_typed_errors() {
        let x = sample(8, 2, 1);
        let w = vec![1.0f32; 8];
        assert!(kernel_pca(&x, &w, 0, 0.5, &TileConfig::default(), &PcaOpts::default())
            .is_err());
        assert!(kernel_pca(&x, &w, 2, 0.0, &TileConfig::default(), &PcaOpts::default())
            .is_err());
        let mut w1 = vec![0.0f32; 8];
        w1[0] = 1.0;
        assert!(
            kernel_pca(&x, &w1, 2, 0.5, &TileConfig::default(), &PcaOpts::default())
                .is_err(),
            "fewer than 2 active rows must be rejected"
        );
        assert!(power_iteration(&[true; 4], &PcaOpts { max_iters: 0, ..PcaOpts::default() }, |_| {
            Ok(vec![0.0; 4])
        })
        .is_err());
    }
}
