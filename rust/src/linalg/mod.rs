//! Kernel-matrix linear algebra on top of the flash MatVec primitive
//! (DESIGN.md §17).
//!
//! The estimators serve *pointwise* functionals of the kernel matrix
//! (densities, scores); this layer serves *global* ones.  Everything here
//! reduces to repeated weighted kernel matrix–vector products
//! `(K·v)_i = Σ_j w_j v_j exp(−‖y_i−x_j‖²/(2h²))`, so it inherits the
//! flash path's tiling, threading and determinism story wholesale —
//! results are block-shape- and thread-count-inert exactly like
//! densities, and every randomized start is seeded.
//!
//! Two consumers:
//!
//! * **In-process / CLI**: [`pca::kernel_pca`] and [`mmd::mmd`] take raw
//!   row-major buffers and run against a local
//!   [`PreparedTrain`](crate::estimator::flash::PreparedTrain).
//! * **Serving path**: `Coordinator::kernel_pca` / `Coordinator::mmd`
//!   drive the same algorithms through MatVec queries against a fitted
//!   model (queue, batcher, metrics — `power_iters` counts sweeps).

pub mod mmd;
pub mod pca;

pub use mmd::{mmd, mmd_from_sums, MmdResult};
pub use pca::{kernel_pca, power_iteration, PcaOpts, PcaResult};
