//! Paper §4.1 + Appendix A FLOP / bytes / arithmetic-intensity models.
//!
//! The Rust twin of `python/compile/flopmodel.py` (both sides pin the
//! paper's quoted constants in their test suites).  The utilization benches
//! (Fig. 5, Fig. 7) divide these model FLOPs by measured runtimes.

/// One exp costs 8 FLOP-equivalents (A6000 SFU:FP32 ratio 128:16, §3).
pub const EXP_FLOPS: f64 = 8.0;

/// Paper's best launch parameters, used by the tile-byte model (§4.1).
pub const PAPER_BLOCK_M: usize = 64;
/// Paper's best BLOCK_N (train-rows tile) from the §6.2 sweep.
pub const PAPER_BLOCK_N: usize = 1024;

/// A6000 peaks used for the paper-scale roofline (§3, §4.1).
pub const A6000_TC_PEAK_FLOPS: f64 = 155.0e12;
/// A6000 scalar FP32 peak, FLOP/s.
pub const A6000_FP32_PEAK_FLOPS: f64 = 40.0e12;
/// A6000 main-memory bandwidth, bytes/s.
pub const A6000_BANDWIDTH_BPS: f64 = 770.0e9;

#[derive(Debug, Clone, Copy, PartialEq)]
/// Model FLOPs and memory traffic for one kernel invocation.
pub struct FlopEstimate {
    /// Floating-point operations (exp counted at [`EXP_FLOPS`]).
    pub flops: f64,
    /// Bytes moved to/from main memory.
    pub bytes: f64,
}

impl FlopEstimate {
    /// Arithmetic intensity, FLOP per byte.
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes
    }
}

/// Total FLOPs for the d-dimensional SD-KDE pipeline (§4.1):
/// score Gram (2dk²) + numerator (2dk² + 4k² + 8k²) + final KDE
/// (2dkm + 4km + 8km), with m defaulting to k/8.
pub fn sdkde_flops_d(k: f64, d: usize, n_test: Option<f64>) -> f64 {
    let m = n_test.unwrap_or(k / 8.0);
    let d = d as f64;
    let gram = 2.0 * d * k * k;
    let numer = 2.0 * d * k * k + 4.0 * k * k + EXP_FLOPS * k * k;
    let eval = 2.0 * d * k * m + 4.0 * k * m + EXP_FLOPS * k * m;
    gram + numer + eval
}

/// GDDR traffic of the tiled score pass (§4.1 tile-byte model):
/// 4(2·BM·d + BN·d + BM) bytes per tile × (k/BM)(k/BN) tiles.
pub fn sdkde_bytes_d(k: f64, d: usize, block_m: usize, block_n: usize) -> f64 {
    let d = d as f64;
    let per_tile =
        4.0 * (2.0 * block_m as f64 * d + block_n as f64 * d + block_m as f64);
    let tiles = (k / block_m as f64) * (k / block_n as f64);
    per_tile * tiles
}

/// Combined §4.1 estimate with the paper's launch parameters.
pub fn sdkde_estimate_d(k: f64, d: usize) -> FlopEstimate {
    FlopEstimate {
        flops: sdkde_flops_d(k, d, None),
        bytes: sdkde_bytes_d(k, d, PAPER_BLOCK_M, PAPER_BLOCK_N),
    }
}

// ---------------------------------------------------------------------------
// Appendix A: the 1-D model.
// ---------------------------------------------------------------------------

/// ~16 flops per (train, train) pair: one exp + ~8 scalar ops.
pub const C1_SCORE_PAIR: f64 = 16.0;
/// ~14 flops per (train, test) pair: one exp + ~6 scalar ops.
pub const C2_KDE_PAIR: f64 = 14.0;

/// Appendix A total: 16 k² + 14 k·m (= 17.75 k² at m = k/8).
pub fn sdkde_flops_1d(k: f64, n_test: Option<f64>) -> f64 {
    let m = n_test.unwrap_or(k / 8.0);
    C1_SCORE_PAIR * k * k + C2_KDE_PAIR * k * m
}

/// Appendix A traffic: one read of train/test, one write of outputs (~5k
/// bytes at m = k/8).
pub fn sdkde_bytes_1d(k: f64, n_test: Option<f64>) -> f64 {
    let m = n_test.unwrap_or(k / 8.0);
    4.0 * (k + m) + 4.0 * m
}

/// Combined FLOP + bytes model for the 1-D SD-KDE pipeline.
pub fn sdkde_estimate_1d(k: f64) -> FlopEstimate {
    FlopEstimate { flops: sdkde_flops_1d(k, None), bytes: sdkde_bytes_1d(k, None) }
}

/// Model FLOPs for a *plain* KDE evaluation (no score pass): distances,
/// exp and accumulate over k·m pairs.  Used by serving-throughput math.
pub fn kde_flops(k: f64, m: f64, d: usize) -> f64 {
    2.0 * d as f64 * k * m + 4.0 * k * m + EXP_FLOPS * k * m
}

/// Fraction of a peak sustained by `flops` of work in `runtime_s`.
pub fn utilization(flops: f64, runtime_s: f64, peak_flops: f64) -> f64 {
    assert!(runtime_s > 0.0 && peak_flops > 0.0);
    flops / runtime_s / peak_flops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d16_flops_constant_81_5() {
        let k = 32768.0;
        let coeff = sdkde_flops_d(k, 16, None) / (k * k);
        assert!((coeff - 81.5).abs() < 0.5, "coeff={coeff}");
    }

    #[test]
    fn d16_bytes_constant_1_13() {
        let k = 32768.0;
        let coeff = sdkde_bytes_d(k, 16, PAPER_BLOCK_M, PAPER_BLOCK_N) / (k * k);
        assert!((coeff - 1.13).abs() < 0.03, "coeff={coeff}");
    }

    #[test]
    fn d16_intensity_72() {
        let i = sdkde_estimate_d(32768.0, 16).intensity();
        assert!((i - 72.0).abs() < 3.0, "i={i}");
    }

    #[test]
    fn machine_balance_200() {
        let balance = A6000_TC_PEAK_FLOPS / A6000_BANDWIDTH_BPS;
        assert!((balance - 200.0).abs() < 5.0, "balance={balance}");
    }

    #[test]
    fn intensity_straddles_fp32_and_tc_roofs() {
        let i = sdkde_estimate_d(32768.0, 16).intensity();
        let fp32_roof = A6000_FP32_PEAK_FLOPS / A6000_BANDWIDTH_BPS; // ~52
        let tc_roof = A6000_TC_PEAK_FLOPS / A6000_BANDWIDTH_BPS; // ~201
        assert!(i > fp32_roof && i < tc_roof, "i={i}");
    }

    #[test]
    fn one_d_flops_constant_17_75() {
        let k = 32768.0;
        let coeff = sdkde_flops_1d(k, None) / (k * k);
        assert!((coeff - 17.75).abs() < 1e-9, "coeff={coeff}");
    }

    #[test]
    fn one_d_flops_order_2e10_at_32k() {
        let f = sdkde_flops_1d(32768.0, None);
        assert!((f - 2e10).abs() / 2e10 < 0.1, "f={f}");
    }

    #[test]
    fn one_d_intensity_scales_3_55_k() {
        let k = 65536.0;
        let i = sdkde_estimate_1d(k).intensity();
        assert!((i / k - 3.55).abs() < 0.15, "i/k={}", i / k);
    }

    #[test]
    fn utilization_math() {
        assert!((utilization(1e12, 0.1, 1e14) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn kde_flops_linear_in_m() {
        let a = kde_flops(1000.0, 100.0, 16);
        let b = kde_flops(1000.0, 200.0, 16);
        assert!((b / a - 2.0).abs() < 1e-12);
    }
}
