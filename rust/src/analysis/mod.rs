//! Analysis layer: the paper's performance models and accuracy metrics.
//!
//! * `flops` — §4.1 + App. A FLOP/byte/intensity models (python twin:
//!   `compile/flopmodel.py`).
//! * `roofline` — machine models (A6000, TPU-like, this CPU testbed) and
//!   attainable-performance math for the utilization figures.
//! * `error_metrics` — importance-sampled MISE/MIAE/negative-mass for the
//!   oracle benchmarks (Figs. 2/3).

pub mod error_metrics;
pub mod flops;
pub mod roofline;

pub use error_metrics::{band, oracle_error, ErrorBand, OracleError};
pub use flops::FlopEstimate;
pub use roofline::{MachineModel, UtilizationRow};
