//! Oracle accuracy metrics: MISE, MIAE and negative-mass diagnostics.
//!
//! The paper's Figs. 2/3 report Mean Integrated Squared Error and Mean
//! Integrated Absolute Error against a known mixture density.  With query
//! points drawn *from the true density p*, the integrals become importance-
//! weighted expectations:
//!
//!   ISE  = ∫ (p̂ - p)² dx = E_{x~p}[ (p̂(x) - p(x))² / p(x) ]
//!   IAE  = ∫ |p̂ - p| dx = E_{x~p}[ |p̂(x) - p(x)| / p(x) ]
//!   neg  = ∫ max(0, -p̂) dx = E_{x~p}[ max(0, -p̂(x)) / p(x) ]
//!
//! Errors are computed on the *signed* estimator (the Laplace correction
//! can go negative; §6.1) and the negative mass is logged separately.

/// Error metrics for one estimator on one evaluation set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OracleError {
    /// Mean integrated squared error vs the analytic truth.
    pub mise: f64,
    /// Mean integrated absolute error vs the analytic truth.
    pub miae: f64,
    /// Integrated negative mass of the signed estimator.
    pub negative_mass: f64,
    /// Query points the integrals were estimated over.
    pub points: usize,
}

/// Importance-sampled oracle errors: `estimate` and `truth` are densities
/// at query points drawn from the true density (`truth[i] > 0`).
pub fn oracle_error(estimate: &[f64], truth: &[f64]) -> OracleError {
    assert_eq!(estimate.len(), truth.len());
    assert!(!estimate.is_empty(), "no evaluation points");
    let mut ise = 0.0f64;
    let mut iae = 0.0f64;
    let mut neg = 0.0f64;
    for (&e, &t) in estimate.iter().zip(truth) {
        assert!(t > 0.0, "true density must be positive at sampled points");
        let diff = e - t;
        ise += diff * diff / t;
        iae += diff.abs() / t;
        neg += (-e).max(0.0) / t;
    }
    let n = estimate.len() as f64;
    OracleError {
        mise: ise / n,
        miae: iae / n,
        negative_mass: neg / n,
        points: estimate.len(),
    }
}

/// Aggregate per-seed errors into mean ± half-width bands (the paper's
/// uncertainty bands in Figs. 2/3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorBand {
    /// Mean over the seed draws.
    pub mean: f64,
    /// 95% CI half-width over the seed draws.
    pub half_width: f64,
}

/// Mean ± 95% CI half-width over per-seed values.
pub fn band(values: &[f64]) -> ErrorBand {
    let s = crate::util::stats::Summary::of(values);
    ErrorBand { mean: s.mean, half_width: s.ci95_half_width() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_estimator_has_zero_error() {
        let truth = vec![0.2, 0.5, 1.0];
        let err = oracle_error(&truth, &truth);
        assert_eq!(err.mise, 0.0);
        assert_eq!(err.miae, 0.0);
        assert_eq!(err.negative_mass, 0.0);
        assert_eq!(err.points, 3);
    }

    #[test]
    fn constant_offset_error() {
        // p̂ = p + 0.1 at every point: ISE = E[0.01/p], IAE = E[0.1/p].
        let truth = vec![0.5, 0.5];
        let est = vec![0.6, 0.6];
        let err = oracle_error(&est, &truth);
        assert!((err.mise - 0.01 / 0.5).abs() < 1e-12);
        assert!((err.miae - 0.1 / 0.5).abs() < 1e-12);
    }

    #[test]
    fn negative_mass_counts_only_negative_parts() {
        let truth = vec![0.5, 0.5, 0.5];
        let est = vec![0.4, -0.1, 0.7];
        let err = oracle_error(&est, &truth);
        assert!((err.negative_mass - (0.1 / 0.5) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn importance_weighting_recovers_known_integral() {
        // Draw from Uniform(0,1) disguised as p=1: ISE of p̂ = p + x is
        // ∫ x² dx = 1/3 over [0,1].
        let n = 200_000;
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        let mut est = Vec::with_capacity(n);
        let mut truth = Vec::with_capacity(n);
        for _ in 0..n {
            let x = rng.uniform();
            truth.push(1.0);
            est.push(1.0 + x);
        }
        let err = oracle_error(&est, &truth);
        assert!((err.mise - 1.0 / 3.0).abs() < 0.005, "mise={}", err.mise);
        assert!((err.miae - 0.5).abs() < 0.005, "miae={}", err.miae);
    }

    #[test]
    fn band_aggregation() {
        let b = band(&[1.0, 1.2, 0.8]);
        assert!((b.mean - 1.0).abs() < 1e-12);
        assert!(b.half_width > 0.0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_truth() {
        oracle_error(&[0.1], &[0.0]);
    }
}
