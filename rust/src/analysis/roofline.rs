//! Roofline analysis (Williams et al.): machine models and attainable-
//! performance calculations for both the paper's A6000 and this testbed.
//!
//! The paper argues its kernel sits between the FP32 roof (~50 flops/byte)
//! and the Tensor-Core roof (~200 flops/byte); the Fig. 5/7 utilization
//! benches reproduce the same analysis on the CPU machine model, and
//! DESIGN.md §8 uses `MachineModel::tpu_v4_like()` to estimate real-TPU
//! performance of the Pallas kernels from their VMEM/MXU structure.

use super::flops::{self, FlopEstimate};

/// A two-roof machine: matrix-engine peak, scalar peak, memory bandwidth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineModel {
    /// Human-readable machine name.
    pub name: &'static str,
    /// Matrix-unit peak (Tensor Core / MXU), FLOP/s.
    pub matrix_peak: f64,
    /// Scalar/vector FP32 peak, FLOP/s.
    pub scalar_peak: f64,
    /// Main-memory bandwidth, bytes/s.
    pub bandwidth: f64,
}

impl MachineModel {
    /// The paper's RTX A6000 (§3).
    pub fn a6000() -> Self {
        MachineModel {
            name: "RTX A6000",
            matrix_peak: flops::A6000_TC_PEAK_FLOPS,
            scalar_peak: flops::A6000_FP32_PEAK_FLOPS,
            bandwidth: flops::A6000_BANDWIDTH_BPS,
        }
    }

    /// A TPU-v4-like core: 275 TFLOP/s bf16 MXU, ~30 TFLOP/s VPU-ish
    /// scalar, 1.2 TB/s HBM.  Used for the DESIGN.md §8 estimates of the
    /// Pallas kernels on real hardware.
    pub fn tpu_v4_like() -> Self {
        MachineModel {
            name: "TPU-v4-like",
            matrix_peak: 275.0e12,
            scalar_peak: 30.0e12,
            bandwidth: 1.2e12,
        }
    }

    /// This testbed: one EPYC-class core driving XLA-CPU.  Peaks are
    /// order-of-magnitude calibration values (measured GEMM throughput of
    /// XLA CPU on this box lands near 5e10 FLOP/s single-core); used only
    /// to contextualize measured utilizations, never to claim them.
    pub fn cpu_testbed() -> Self {
        MachineModel {
            name: "CPU testbed (1 core)",
            matrix_peak: 5.0e10,
            scalar_peak: 1.0e10,
            bandwidth: 2.0e10,
        }
    }

    /// Machine balance against the matrix roof, flops/byte.
    pub fn matrix_balance(&self) -> f64 {
        self.matrix_peak / self.bandwidth
    }

    /// Machine balance against the scalar roof, flops/byte.
    pub fn scalar_balance(&self) -> f64 {
        self.scalar_peak / self.bandwidth
    }

    /// Attainable FLOP/s at a given arithmetic intensity (classic roofline
    /// min(peak, intensity * bandwidth)) against the matrix roof.
    pub fn attainable(&self, intensity: f64) -> f64 {
        (intensity * self.bandwidth).min(self.matrix_peak)
    }

    /// Roofline-predicted runtime for a work estimate.
    pub fn predicted_runtime_s(&self, est: &FlopEstimate) -> f64 {
        let compute = est.flops / self.matrix_peak;
        let memory = est.bytes / self.bandwidth;
        compute.max(memory)
    }

    /// Is a kernel with this intensity compute-bound on this machine?
    pub fn compute_bound(&self, intensity: f64) -> bool {
        intensity >= self.matrix_balance()
    }
}

/// Utilization report row produced by the Fig. 5 / Fig. 7 benches.
#[derive(Debug, Clone, PartialEq)]
pub struct UtilizationRow {
    /// Training-set size of the measured run.
    pub n_train: usize,
    /// Measured runtime, milliseconds.
    pub runtime_ms: f64,
    /// Model FLOPs for that run.
    pub model_flops: f64,
    /// Fraction of the machine's matrix peak sustained.
    pub utilization: f64,
}

/// Assemble one utilization report row from a measured runtime.
pub fn utilization_row(
    machine: &MachineModel,
    n_train: usize,
    model_flops: f64,
    runtime_s: f64,
) -> UtilizationRow {
    UtilizationRow {
        n_train,
        runtime_ms: runtime_s * 1e3,
        model_flops,
        utilization: flops::utilization(model_flops, runtime_s, machine.matrix_peak),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a6000_balances_match_paper() {
        let m = MachineModel::a6000();
        assert!((m.matrix_balance() - 200.0).abs() < 5.0);
        assert!((m.scalar_balance() - 52.0).abs() < 3.0);
    }

    #[test]
    fn paper_kernel_is_compute_bound_relative_to_scalar_roof() {
        // §4.1: 72 flops/byte is above the FP32 roof (~52) but below the
        // TC roof (~200) — "straddles these two limits".
        let m = MachineModel::a6000();
        let i = flops::sdkde_estimate_d(32768.0, 16).intensity();
        assert!(i > m.scalar_balance());
        assert!(!m.compute_bound(i)); // not above the *matrix* roof
    }

    #[test]
    fn attainable_clips_at_peak() {
        let m = MachineModel::a6000();
        assert_eq!(m.attainable(1e6), m.matrix_peak);
        let low = m.attainable(1.0);
        assert!((low - m.bandwidth).abs() / m.bandwidth < 1e-12);
    }

    #[test]
    fn predicted_runtime_takes_max_of_roofs() {
        let m = MachineModel {
            name: "toy",
            matrix_peak: 100.0,
            scalar_peak: 10.0,
            bandwidth: 10.0,
        };
        // 1000 flops / 100 = 10 s compute; 10 bytes / 10 = 1 s memory.
        let est = FlopEstimate { flops: 1000.0, bytes: 10.0 };
        assert!((m.predicted_runtime_s(&est) - 10.0).abs() < 1e-12);
        // Memory-bound case.
        let est = FlopEstimate { flops: 10.0, bytes: 1000.0 };
        assert!((m.predicted_runtime_s(&est) - 100.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_row_math() {
        let m = MachineModel { name: "toy", matrix_peak: 1e9, scalar_peak: 1e8, bandwidth: 1e9 };
        let row = utilization_row(&m, 1024, 1e6, 0.01);
        assert_eq!(row.n_train, 1024);
        assert!((row.runtime_ms - 10.0).abs() < 1e-9);
        // 1e6 flops / 0.01 s = 1e8 FLOP/s = 10% of 1e9.
        assert!((row.utilization - 0.1).abs() < 1e-12);
    }

    #[test]
    fn tpu_model_sane() {
        let t = MachineModel::tpu_v4_like();
        assert!(t.matrix_balance() > 200.0); // HBM-era balance
    }
}
