//! `cargo bench --bench native_flash` — the four native series: scalar
//! baseline, auto-vectorized flash tile, explicit-SIMD tile, and
//! SIMD + cached prepare (the resident-model serving hot path).
//!
//! The only bench target that needs neither `make artifacts` nor XLA:
//! every series is compiled into the binary, so this runs on a fresh
//! checkout (and in the no-XLA CI leg).  It is the CPU analogue of the
//! paper's Fig. 1 ordering claim: the matmul-identity reordering beats
//! the scalar O(n·m·d) sweep, increasingly so as n grows.  For the SIMD
//! series to differ from the tile series, build with a nightly toolchain
//! and `--features simd` (see BENCHMARKS.md).
//!
//! Env overrides: FLASH_SDKDE_BENCH_SIZES="1024,4096" to change the
//! n sweep, FLASH_SDKDE_NAIVE_MAX_N to cap the scalar baseline,
//! FLASH_SDKDE_BENCH_SEEDS for a multi-seed sweep, and
//! FLASH_SDKDE_TUNING=<table.json> to add the `tuned` series (the
//! cached hot path under a `flash-sdkde tune` table's block shapes —
//! run with and without it for the BENCHMARKS.md tuned-vs-default
//! record).

use flash_sdkde::bench_harness::{native_cmp, RunSpec};
use flash_sdkde::tuner::TuningTable;

fn env_sizes() -> Vec<usize> {
    std::env::var("FLASH_SDKDE_BENCH_SIZES")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| native_cmp::DEFAULT_SIZES.to_vec())
}

fn main() -> anyhow::Result<()> {
    let cap = std::env::var("FLASH_SDKDE_NAIVE_MAX_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(native_cmp::DEFAULT_NAIVE_MAX_N);
    let seeds = std::env::var("FLASH_SDKDE_BENCH_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(native_cmp::DEFAULT_SEEDS);
    let tuning = match std::env::var("FLASH_SDKDE_TUNING") {
        Ok(path) => Some(TuningTable::load(std::path::Path::new(&path))?),
        Err(_) => None,
    };
    let table = native_cmp::native_vs_scalar(
        RunSpec::new(1, 3),
        &env_sizes(),
        cap,
        seeds,
        tuning.as_ref(),
    )?;
    table.emit("native_flash");
    Ok(())
}
