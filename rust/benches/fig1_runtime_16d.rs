//! `cargo bench --bench fig1_runtime_16d` — regenerates the paper's fig1 series.
//! Thin wrapper over `bench_harness::experiments` (harness = false; the
//! offline registry has no criterion — see DESIGN.md §3).
//!
//! Knobs (argv after `--` wins; env var is the fallback, matching
//! cluster_smoke): `--artifacts <dir>` / FLASH_SDKDE_ARTIFACTS,
//! `--iters <n>` / FLASH_SDKDE_BENCH_ITERS, `--native-series` /
//! FLASH_SDKDE_NATIVE_SERIES=1 adds the pure-Rust native backend as a
//! third measured series, `--tuning <table.json>` / FLASH_SDKDE_TUNING
//! runs that series under a `flash-sdkde tune` table's block shapes.
//! Dangling flags (`--tuning` with no value, `--native-series=1`) are
//! errors, not silent no-ops.

use flash_sdkde::bench_harness::{experiments::Ctx, run_experiment, RunSpec};
use flash_sdkde::tuner::TuningTable;
use flash_sdkde::util::cli::{scan_raw_flag, scan_raw_option};

fn main() -> anyhow::Result<()> {
    let args = || std::env::args().skip(1);
    let artifacts = scan_raw_option("artifacts", args())
        .map_err(anyhow::Error::msg)?
        .or_else(|| std::env::var("FLASH_SDKDE_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".to_string());
    let mut ctx = Ctx::new(std::path::Path::new(&artifacts))?;
    if let Some(iters) = scan_raw_option("iters", args())
        .map_err(anyhow::Error::msg)?
        .or_else(|| std::env::var("FLASH_SDKDE_BENCH_ITERS").ok())
    {
        ctx.spec = RunSpec::new(1, iters.parse()?);
    }
    ctx.native_series = scan_raw_flag("native-series", args())
        .map_err(anyhow::Error::msg)?
        || std::env::var("FLASH_SDKDE_NATIVE_SERIES")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
    if let Some(path) = scan_raw_option("tuning", args())
        .map_err(anyhow::Error::msg)?
        .or_else(|| std::env::var("FLASH_SDKDE_TUNING").ok())
    {
        ctx.native_series = true;
        ctx.native_tuning = Some(TuningTable::load(std::path::Path::new(&path))?);
    }
    run_experiment(&mut ctx, "fig1")?.emit("fig1");
    Ok(())
}
