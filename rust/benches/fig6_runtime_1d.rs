//! `cargo bench --bench fig6_runtime_1d` — regenerates the paper's fig6 series.
//! Thin wrapper over `bench_harness::experiments` (harness = false; the
//! offline registry has no criterion — see DESIGN.md §3).
//!
//! Env overrides: FLASH_SDKDE_NATIVE_SERIES=1 adds the pure-Rust native
//! backend as a third measured series; FLASH_SDKDE_TUNING=<table.json>
//! runs that series under a `flash-sdkde tune` table's block shapes.

use flash_sdkde::bench_harness::{experiments::Ctx, run_experiment, RunSpec};
use flash_sdkde::tuner::TuningTable;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let mut ctx = Ctx::new(std::path::Path::new(&artifacts))?;
    if let Ok(iters) = std::env::var("FLASH_SDKDE_BENCH_ITERS") {
        ctx.spec = RunSpec::new(1, iters.parse()?);
    }
    if let Ok(v) = std::env::var("FLASH_SDKDE_NATIVE_SERIES") {
        ctx.native_series = v == "1" || v.eq_ignore_ascii_case("true");
    }
    if let Ok(path) = std::env::var("FLASH_SDKDE_TUNING") {
        ctx.native_series = true;
        ctx.native_tuning = Some(TuningTable::load(std::path::Path::new(&path))?);
    }
    run_experiment(&mut ctx, "fig6")?.emit("fig6");
    Ok(())
}
