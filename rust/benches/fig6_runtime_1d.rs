//! `cargo bench --bench fig6_runtime_1d` — regenerates the paper's fig6 series.
//! Thin wrapper over `bench_harness::experiments` (harness = false; the
//! offline registry has no criterion — see DESIGN.md §3).

use flash_sdkde::bench_harness::{experiments::Ctx, run_experiment, RunSpec};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let mut ctx = Ctx::new(std::path::Path::new(&artifacts))?;
    if let Ok(iters) = std::env::var("FLASH_SDKDE_BENCH_ITERS") {
        ctx.spec = RunSpec::new(1, iters.parse()?);
    }
    run_experiment(&mut ctx, "fig6")?.emit("fig6");
    Ok(())
}
