//! `cargo bench --bench kernel_linalg` — the kernel-ops sweep
//! (BENCHMARKS.md "Kernel ops"): MatVec / kernel PCA / MMD runtimes on
//! the native flash tiles (DESIGN.md §17), one row per train size.
//!
//! Needs no artifacts or XLA — every series is compiled into this binary,
//! so it runs on a fresh checkout and in the no-XLA CI leg.
//!
//! Knobs (argv after `--` wins; env var is the fallback): `--quick` /
//! FLASH_SDKDE_QUICK=1 runs the CI-smoke sweep (tiny n, single
//! iteration), `--sizes <a,b,...>` overrides the n sweep, `--iters <n>` /
//! FLASH_SDKDE_BENCH_ITERS sets measured iterations.  Dangling flags
//! (`--sizes` with no value, `--quick=1`) are errors, not silent no-ops.

use anyhow::{anyhow, Result};

use flash_sdkde::bench_harness::{linalg, RunSpec};
use flash_sdkde::util::cli::{scan_raw_flag, scan_raw_option};

fn main() -> Result<()> {
    let args = || std::env::args().skip(1);
    let quick = scan_raw_flag("quick", args()).map_err(anyhow::Error::msg)?
        || std::env::var("FLASH_SDKDE_QUICK")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
    let mut spec = if quick { RunSpec::new(0, 1) } else { RunSpec::new(1, 3) };
    if let Some(iters) = scan_raw_option("iters", args())
        .map_err(anyhow::Error::msg)?
        .or_else(|| std::env::var("FLASH_SDKDE_BENCH_ITERS").ok())
    {
        spec = RunSpec::new(spec.warmup, iters.parse()?);
    }
    let sizes = match scan_raw_option("sizes", args()).map_err(anyhow::Error::msg)? {
        Some(list) => list
            .split(',')
            .map(|t| t.trim().parse::<usize>().map_err(|e| anyhow!("--sizes: {e}")))
            .collect::<Result<Vec<_>>>()?,
        None if quick => linalg::QUICK_SIZES.to_vec(),
        None => linalg::DEFAULT_SIZES.to_vec(),
    };
    linalg::kernel_ops(spec, &sizes)?.emit("linalg");
    Ok(())
}
