//! `cargo bench --bench coordinator_micro` — L3 microbenchmarks.
//!
//! The paper's contribution is the kernel reformulation; the coordinator is
//! our serving wrapper, so this bench verifies L3 is *not* the bottleneck
//! (DESIGN.md §8: "L3 should not be the bottleneck unless the paper's
//! contribution is the coordinator").  Measures:
//!
//!  * bounded-queue push/pop throughput (the admission path)
//!  * latency-histogram record cost (per-request metrics overhead)
//!  * end-to-end in-process eval latency and dynamic-batching behaviour
//!    under concurrent clients, against the smallest artifact bucket.

use std::sync::Arc;
use std::time::{Duration, Instant};

use flash_sdkde::bench_harness::{black_box, Table};
use flash_sdkde::config::Config;
use flash_sdkde::coordinator::metrics::LatencyHistogram;
use flash_sdkde::coordinator::scheduler::BoundedQueue;
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::estimator::EstimatorKind;
use flash_sdkde::util::rng::Pcg64;

fn bench_queue(table: &mut Table) {
    let q: BoundedQueue<u64> = BoundedQueue::new(1024);
    let ops = 1_000_000u64;
    let start = Instant::now();
    for i in 0..ops {
        q.push(i).expect("capacity");
        black_box(q.pop_timeout(Duration::from_millis(1)).expect("item"));
    }
    let per_op_ns = start.elapsed().as_nanos() as f64 / ops as f64 / 2.0;
    table.row(vec![
        "queue push+pop".into(),
        format!("{per_op_ns:.0} ns/op"),
        format!("{:.2} Mops/s", 1e3 / per_op_ns),
    ]);
}

fn bench_histogram(table: &mut Table) {
    let h = LatencyHistogram::new();
    let ops = 1_000_000u64;
    let start = Instant::now();
    for i in 0..ops {
        h.record(Duration::from_micros(i % 1000));
    }
    let per_op_ns = start.elapsed().as_nanos() as f64 / ops as f64;
    table.row(vec![
        "histogram record".into(),
        format!("{per_op_ns:.0} ns/op"),
        format!("{:.2} Mops/s", 1e3 / per_op_ns),
    ]);
}

fn bench_eval_path(table: &mut Table, artifacts: &str) -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = artifacts.into();
    cfg.batch_wait_ms = 1;
    // Without artifacts the native backend serves the same L3 path, so
    // this bench runs on a fresh checkout (and in the no-XLA CI leg).
    let cfg = cfg.auto_backend();
    table.note(&format!("backend: {}", cfg.backend));
    let coordinator = Arc::new(Coordinator::start(cfg)?);

    // Fit the smallest 16-D model.
    let mix = by_dim(16);
    let mut rng = Pcg64::seeded(1);
    let n = 400;
    let model = coordinator.fit(
        "micro",
        mix.sample(n, &mut rng),
        &FitSpec::new(EstimatorKind::SdKde, 16),
    )?;

    // Single-client eval latency (k=8 queries), post-warmup.  The handle
    // skips the registry lookup — this measures the pure queue+batch path.
    let queries = mix.sample(8, &mut rng);
    coordinator.eval(&model, queries.clone())?;
    let iters = 50;
    let start = Instant::now();
    for _ in 0..iters {
        black_box(coordinator.eval(&model, queries.clone())?);
    }
    let per_eval_ms = start.elapsed().as_secs_f64() * 1e3 / iters as f64;
    table.row(vec![
        "eval e2e (k=8, 1 client)".into(),
        format!("{per_eval_ms:.3} ms"),
        format!("{:.0} req/s", 1e3 / per_eval_ms),
    ]);

    // Concurrent clients: batching should lift throughput per execution.
    let clients = 8;
    let per_client = 25;
    let start = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let coord = Arc::clone(&coordinator);
            let mix = mix.clone();
            let model = model.clone();
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(99, c as u64);
                for _ in 0..per_client {
                    let q = mix.sample(8, &mut rng);
                    coord.eval(&model, q).expect("eval");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("client thread");
    }
    let total = (clients * per_client) as f64;
    let wall = start.elapsed().as_secs_f64();
    table.row(vec![
        format!("eval e2e (k=8, {clients} clients)"),
        format!("{:.3} ms/req", wall * 1e3 / total),
        format!("{:.0} req/s", total / wall),
    ]);
    table.row(vec![
        "mean batch size under load".into(),
        format!("{:.2}", coordinator.metrics().mean_batch_size()),
        "-".into(),
    ]);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let mut table = Table::new(
        "Coordinator microbenchmarks (L3 must not bottleneck)",
        &["path", "cost", "rate"],
    );
    bench_queue(&mut table);
    bench_histogram(&mut table);
    bench_eval_path(&mut table, &artifacts)?;
    table.emit("coordinator_micro");
    Ok(())
}
