//! `cargo bench --bench cluster_smoke` — loopback multi-node smoke
//! latency: per-query wire latency against a single `serve` worker
//! directly vs through the consistent-hash router over a 3-worker
//! cluster (BENCHMARKS.md "Cluster loopback smoke").
//!
//! Everything is in-process on 127.0.0.1 ephemeral ports with the native
//! backend, so this runs on a fresh checkout and in the no-XLA CI leg.
//! The delta between the two series is the router's forwarding cost (one
//! extra hop: parse + rendezvous + pooled round-trip), which should stay
//! small against the kernel time.
//!
//! Env overrides: FLASH_SDKDE_CLUSTER_QUERIES (measured queries per
//! series, default 200), FLASH_SDKDE_CLUSTER_WORKERS (cluster size,
//! default 3).  An optional `--tuning <table.json>` argument (or
//! FLASH_SDKDE_TUNING) makes every worker — direct and routed — load
//! the tile-tuning table, so the smoke stays representative of a tuned
//! fleet.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use flash_sdkde::bench_harness::Table;
use flash_sdkde::config::{Config, RouterConfig};
use flash_sdkde::coordinator::router::{Router, RouterServer};
use flash_sdkde::coordinator::server::{Client, Server};
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::estimator::EstimatorKind;
use flash_sdkde::runtime::BackendKind;
use flash_sdkde::util::rng::Pcg64;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&v| v > 0)
        .unwrap_or(default)
}

/// `--tuning <path>` / `--tuning=<path>` argument, falling back to the
/// FLASH_SDKDE_TUNING env var.  A dangling `--tuning` is an error, not
/// a silent untuned run.
fn tuning_arg() -> Result<Option<PathBuf>> {
    let from_args = flash_sdkde::util::cli::scan_raw_option(
        "tuning",
        std::env::args().skip(1),
    )
    .map_err(anyhow::Error::msg)?;
    Ok(from_args
        .or_else(|| std::env::var("FLASH_SDKDE_TUNING").ok())
        .map(PathBuf::from))
}

fn worker(tuning: Option<&PathBuf>) -> Result<Server> {
    let mut cfg = Config::default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent-flash-sdkde-artifacts".into();
    cfg.batch_wait_ms = 0;
    cfg.tuning_path = tuning.cloned();
    Server::start(Coordinator::start(cfg)?, "127.0.0.1", 0)
}

/// Fit `models` through `client`, then measure per-query latency round
/// robin over them; returns (mean_ms, p50_ms, p95_ms).
fn measure_series(
    client: &mut Client,
    models: &[String],
    d: usize,
    queries: usize,
) -> Result<(f64, f64, f64)> {
    let mix = by_dim(d);
    let mut rng = Pcg64::seeded(5);
    for name in models {
        client.fit(name, mix.sample(512, &mut rng), &FitSpec::new(EstimatorKind::Kde, d))?;
    }
    let points = mix.sample(8, &mut rng);
    // Warmup: touch every model once (prepare cache + connection pool).
    for name in models {
        client.eval(name, d, points.clone())?;
    }
    let mut samples = Vec::with_capacity(queries);
    for i in 0..queries {
        let name = &models[i % models.len()];
        let start = Instant::now();
        client.eval(name, d, points.clone())?;
        samples.push(start.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Ok((mean, pct(0.50), pct(0.95)))
}

fn main() -> Result<()> {
    let queries = env_usize("FLASH_SDKDE_CLUSTER_QUERIES", 200);
    let n_workers = env_usize("FLASH_SDKDE_CLUSTER_WORKERS", 3);
    let tuning = tuning_arg()?;
    let d = 2;
    let models: Vec<String> = (0..6).map(|i| format!("smoke-{i}")).collect();

    // Series 1: one worker, direct connection.
    let single = worker(tuning.as_ref())?;
    let mut direct = Client::connect(single.local_addr())?;
    let (d_mean, d_p50, d_p95) = measure_series(&mut direct, &models, d, queries)?;

    // Series 2: n workers behind the router.
    let workers: Vec<Server> =
        (0..n_workers).map(|_| worker(tuning.as_ref())).collect::<Result<_>>()?;
    let mut cfg = RouterConfig::default();
    cfg.nodes = workers.iter().map(|w| w.local_addr().to_string()).collect();
    cfg.connect_timeout_ms = 500;
    let router_server = RouterServer::start(Router::new(cfg)?, "127.0.0.1", 0)?;
    let mut routed = Client::connect(router_server.local_addr())?;
    let (r_mean, r_p50, r_p95) = measure_series(&mut routed, &models, d, queries)?;

    let mut table = Table::new(
        "cluster loopback smoke: direct single node vs routed cluster \
         (per-query wire latency, ms)",
        &["series", "nodes", "queries", "mean_ms", "p50_ms", "p95_ms"],
    );
    table.row(vec![
        "direct".into(),
        "1".into(),
        queries.to_string(),
        format!("{d_mean:.4}"),
        format!("{d_p50:.4}"),
        format!("{d_p95:.4}"),
    ]);
    table.row(vec![
        "routed".into(),
        n_workers.to_string(),
        queries.to_string(),
        format!("{r_mean:.4}"),
        format!("{r_p50:.4}"),
        format!("{r_p95:.4}"),
    ]);
    table.note(
        "routed - direct = router forwarding overhead (parse + rendezvous \
         + pooled hop); kernels are identical on both paths",
    );
    match &tuning {
        Some(path) => table.note(&format!(
            "all workers tuned: --tuning {}",
            path.display()
        )),
        None => table.note("workers run the static default TileConfig \
                            (pass --tuning <table.json> for a tuned fleet)"),
    }
    table.emit("cluster_smoke");
    Ok(())
}
