"""Hypothesis sweeps: kernels vs oracles over random shapes and bandwidths.

These are the property-based half of the L1 test plan: any (n, m, d, h,
tile config, mask) within the supported envelope must agree with the
pure-jnp oracle to fp32 tolerance.
"""

import math

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import TileConfig, debias, kde, laplace_fused, score
from compile.kernels import ref

# Modest deadline-free profile: pallas interpret tracing is slow per example.
COMMON = dict(max_examples=20, deadline=None)


def _data(n, m, d, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=scale, size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(scale=scale, size=(m, d)), jnp.float32)
    return x, y


shape_strategy = st.tuples(
    st.integers(min_value=3, max_value=300),   # n
    st.integers(min_value=1, max_value=80),    # m
    st.sampled_from([1, 2, 3, 4, 8, 16]),      # d
    st.integers(min_value=0, max_value=2**31), # seed
    st.floats(min_value=0.15, max_value=2.5),  # h
)


@given(shape_strategy)
@settings(**COMMON)
def test_kde_matches_ref(params):
    n, m, d, seed, h = params
    x, y = _data(n, m, d, seed, scale=1.5)
    w = jnp.ones(n, jnp.float32)
    got = np.asarray(kde(x, w, y, jnp.float32(h)))
    want = np.asarray(ref.kde_ref(x, w, y, jnp.float32(h)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-8)


@given(shape_strategy)
@settings(**COMMON)
def test_laplace_matches_ref(params):
    n, m, d, seed, h = params
    x, y = _data(n, m, d, seed, scale=1.5)
    w = jnp.ones(n, jnp.float32)
    got = np.asarray(laplace_fused(x, w, y, jnp.float32(h)))
    want = np.asarray(ref.laplace_ref(x, w, y, jnp.float32(h)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-7)


@given(
    st.integers(min_value=4, max_value=200),
    st.sampled_from([1, 2, 4, 16]),
    st.integers(min_value=0, max_value=2**31),
    st.floats(min_value=0.3, max_value=1.5),
)
@settings(**COMMON)
def test_score_matches_ref(n, d, seed, h_s):
    x, _ = _data(n, 1, d, seed, scale=1.0)
    w = jnp.ones(n, jnp.float32)
    got = np.asarray(score(x, w, jnp.float32(h_s)))
    want = np.asarray(ref.score_ref(x, w, jnp.float32(h_s)))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=5e-5)


@given(
    st.integers(min_value=2, max_value=150),   # keep
    st.integers(min_value=0, max_value=60),    # extra padding rows
    st.integers(min_value=0, max_value=2**31),
)
@settings(**COMMON)
def test_mask_extension_invariant(keep, pad, seed):
    # Appending w=0 rows never changes the result: the bucketing contract.
    n, m, d = keep + pad, 9, 3
    x, y = _data(n, m, d, seed, scale=1.2)
    w = jnp.asarray(
        np.concatenate([np.ones(keep), np.zeros(pad)]), jnp.float32
    )
    h = jnp.float32(0.7)
    got = np.asarray(kde(x, w, y, h))
    want = np.asarray(kde(x[:keep], jnp.ones(keep, jnp.float32), y, h))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-8)


@given(
    st.sampled_from([8, 16, 32, 64, 128]),
    st.sampled_from([8, 16, 32, 64, 128, 256]),
    st.integers(min_value=0, max_value=2**31),
)
@settings(**COMMON)
def test_tile_sweep_invariant(bm, bn, seed):
    # The §6.2 ablation sweeps tiles for speed; results must be identical.
    x, y = _data(130, 25, 4, seed, scale=1.0)
    w = jnp.ones(130, jnp.float32)
    h = jnp.float32(0.8)
    got = np.asarray(kde(x, w, y, h, tiles=TileConfig(bm, bn)))
    want = np.asarray(ref.kde_ref(x, w, y, h))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-8)


@given(
    st.integers(min_value=10, max_value=120),
    st.integers(min_value=0, max_value=2**31),
    st.floats(min_value=0.3, max_value=1.2),
)
@settings(**COMMON)
def test_debias_preserves_shape_and_finiteness(n, seed, h):
    x, _ = _data(n, 1, 2, seed, scale=1.0)
    w = jnp.ones(n, jnp.float32)
    out = np.asarray(debias(x, w, jnp.float32(h)))
    assert out.shape == (n, 2)
    assert np.isfinite(out).all()
    # Debiased samples stay near the originals: shift is O(h^2 * score).
    want = np.asarray(ref.debias_ref(x, w, jnp.float32(h)))
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=5e-5)
