"""Flash KDE Pallas kernel vs the pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import TileConfig, kde, kde_raw, kde_with_tiles
from compile.kernels import ref
from .conftest import make_problem


def test_matches_ref_16d(problem_16d):
    x, w, y, h = problem_16d
    np.testing.assert_allclose(
        np.asarray(kde(x, w, y, h)),
        np.asarray(ref.kde_ref(x, w, y, h)),
        rtol=3e-5, atol=1e-8,
    )


def test_matches_ref_1d(problem_1d):
    x, w, y, h = problem_1d
    np.testing.assert_allclose(
        np.asarray(kde(x, w, y, h)),
        np.asarray(ref.kde_ref(x, w, y, h)),
        rtol=3e-5, atol=1e-8,
    )


@pytest.mark.parametrize("n,m", [(64, 64), (65, 17), (256, 32), (300, 100),
                                 (1000, 125), (31, 7)])
def test_non_divisible_shapes(rng, n, m):
    # Padding must make any (n, m) pair exact, not just tile multiples.
    x, w, y, h = make_problem(rng, n, m, d=4)
    np.testing.assert_allclose(
        np.asarray(kde(x, w, y, h)),
        np.asarray(ref.kde_ref(x, w, y, h)),
        rtol=3e-5, atol=1e-8,
    )


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 64), (64, 256), (128, 32)])
def test_tile_config_is_pure_implementation_detail(rng, bm, bn):
    # Fig. 4's point in miniature: tiling changes runtime, never the result.
    x, w, y, h = make_problem(rng, 200, 48, d=8)
    base = np.asarray(ref.kde_ref(x, w, y, h))
    got = np.asarray(kde(x, w, y, h, tiles=TileConfig(bm, bn)))
    np.testing.assert_allclose(got, base, rtol=3e-5, atol=1e-8)


def test_kde_with_tiles_closure(rng):
    x, w, y, h = make_problem(rng, 128, 32, d=2)
    f = kde_with_tiles(16, 32)
    np.testing.assert_allclose(
        np.asarray(f(x, w, y, h)),
        np.asarray(ref.kde_ref(x, w, y, h)),
        rtol=3e-5,
    )


def test_masked_rows_are_exactly_ignored(rng):
    x, w, y, h = make_problem(rng, 160, 24, d=6)
    keep = 97
    w_mask = jnp.asarray(
        np.concatenate([np.ones(keep), np.zeros(160 - keep)]), jnp.float32
    )
    got = np.asarray(kde(x, w_mask, y, h))
    want = np.asarray(ref.kde_ref(x[:keep], jnp.ones(keep, jnp.float32), y, h))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-8)


def test_raw_vs_normalized_relationship(rng):
    x, w, y, h = make_problem(rng, 96, 16, d=3)
    raw = np.asarray(kde_raw(x, w, y, h))
    full = np.asarray(kde(x, w, y, h))
    d = 3
    norm = (2 * np.pi) ** (-d / 2) / float(h) ** d / float(jnp.sum(w))
    np.testing.assert_allclose(full, raw * norm, rtol=1e-6)


def test_bandwidth_is_runtime_input(rng):
    # The same kernel must serve multiple bandwidths (artifact reuse).
    x, w, y, _ = make_problem(rng, 80, 16, d=2)
    for h in (0.2, 0.7, 1.9):
        np.testing.assert_allclose(
            np.asarray(kde(x, w, y, jnp.float32(h))),
            np.asarray(ref.kde_ref(x, w, y, jnp.float32(h))),
            rtol=3e-5, atol=1e-8,
        )


def test_output_is_nonnegative_and_finite(problem_16d):
    x, w, y, h = problem_16d
    out = np.asarray(kde(x, w, y, h))
    assert np.isfinite(out).all()
    assert (out >= 0.0).all()


def test_rejects_bad_shapes(rng):
    x, w, y, h = make_problem(rng, 32, 8, d=4)
    with pytest.raises(ValueError, match="dimension mismatch"):
        kde(x, w, jnp.zeros((8, 5), jnp.float32), h)
    with pytest.raises(ValueError, match="weights"):
        kde(x, jnp.ones(31, jnp.float32), y, h)
    with pytest.raises(ValueError, match=r"X must be \[n, d\]"):
        kde(jnp.zeros((4,), jnp.float32), w, y, h)
