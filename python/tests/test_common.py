"""Tile-math unit tests for kernels/common.py (pure python, no tracing)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import common


def test_pow2_floor():
    assert common._pow2_floor(1) == 1
    assert common._pow2_floor(2) == 2
    assert common._pow2_floor(3) == 2
    assert common._pow2_floor(64) == 64
    assert common._pow2_floor(1000) == 512
    with pytest.raises(ValueError):
        common._pow2_floor(0)


def test_round_up():
    assert common.round_up(0, 8) == 0
    assert common.round_up(1, 8) == 8
    assert common.round_up(8, 8) == 8
    assert common.round_up(9, 8) == 16
    with pytest.raises(ValueError):
        common.round_up(4, 0)


def test_tile_config_validation():
    with pytest.raises(ValueError):
        common.TileConfig(0, 8)
    with pytest.raises(ValueError):
        common.TileConfig(8, -1)


def test_clamp_produces_pow2_tiles():
    cfg = common.TileConfig(64, 256).clamp(100, 100)
    assert cfg.block_m == 64
    assert cfg.block_n == 64
    cfg = common.TileConfig(256, 512).clamp(33, 1000)
    assert cfg.block_m == 32
    assert cfg.block_n == 512


def test_grid_divisibility_enforced():
    cfg = common.TileConfig(8, 16)
    assert cfg.grid(16, 32) == (2, 2)
    with pytest.raises(ValueError):
        cfg.grid(17, 32)
    with pytest.raises(ValueError):
        cfg.grid(16, 33)


def test_padded_sizes_are_divisible():
    cfg = common.TileConfig(8, 32)
    mp, np_ = common.padded_sizes(13, 70, cfg)
    assert mp % 8 == 0 and np_ % 32 == 0
    assert mp >= 13 and np_ >= 70
    # Exact sizes don't grow.
    assert common.padded_sizes(16, 64, cfg) == (16, 64)


def test_pick_tiles_dimension_aware_default():
    # 1-D default is shorter in BM than the high-d default (perf pass).
    one_d = common.pick_tiles(10_000, 10_000, None, d=1)
    high_d = common.pick_tiles(10_000, 10_000, None, d=16)
    assert one_d.block_m < high_d.block_m
    # Explicit config wins over the d heuristic.
    explicit = common.pick_tiles(10_000, 10_000, common.TileConfig(8, 8), d=1)
    assert (explicit.block_m, explicit.block_n) == (8, 8)


def test_vmem_bytes_model():
    cfg = common.TileConfig(64, 1024)
    d = 16
    # 4 * (BM*d + BN*d + BN + BM*(d+1)) bytes.
    want = 4 * (64 * 16 + 1024 * 16 + 1024 + 64 * 17)
    assert cfg.vmem_bytes(d) == want
    # The paper-scale config stays far below a 16 MiB VMEM budget.
    assert cfg.vmem_bytes(16) < 16 * 1024 * 1024 / 10


def test_pad_rows():
    x = jnp.ones((3, 2), jnp.float32)
    p = common.pad_rows(x, 5, value=7.0)
    assert p.shape == (5, 2)
    np.testing.assert_array_equal(np.asarray(p[3:]), np.full((2, 2), 7.0))
    assert common.pad_rows(x, 3) is x
    with pytest.raises(ValueError):
        common.pad_rows(x, 2)


def test_normalizer_matches_closed_form():
    h, d = 0.7, 3
    got = float(common.normalizer(jnp.float32(h), d))
    want = 1.0 / ((2 * math.pi) ** (d / 2) * h**d)
    assert got == pytest.approx(want, rel=1e-5)


def test_validate_pairwise_args_messages():
    x = jnp.zeros((4, 2))
    w = jnp.zeros((4,))
    y = jnp.zeros((3, 2))
    common.validate_pairwise_args(x, w, y)  # ok
    with pytest.raises(ValueError, match="dimension mismatch"):
        common.validate_pairwise_args(x, w, jnp.zeros((3, 5)))
    with pytest.raises(ValueError, match="weights"):
        common.validate_pairwise_args(x, jnp.zeros((5,)), y)
    with pytest.raises(ValueError, match="Y must be"):
        common.validate_pairwise_args(x, w, jnp.zeros((3,)))
