"""Benchmark mixture tests: pdf correctness, sampling, determinism."""

import numpy as np
import pytest

from compile import mixtures


@pytest.mark.parametrize("mix", [mixtures.mix1d(), mixtures.mix16d(),
                                 mixtures.by_dim(4)])
def test_weights_normalized(mix):
    assert sum(mix.weights) == pytest.approx(1.0)
    assert len(mix.means) == mix.k == len(mix.sigmas)


def test_pdf_integrates_to_one_1d():
    mix = mixtures.mix1d()
    grid = np.linspace(-15, 15, 20001).reshape(-1, 1)
    pdf = mix.pdf(grid)
    assert np.trapezoid(pdf, grid[:, 0]) == pytest.approx(1.0, abs=1e-4)


def test_sampling_deterministic():
    mix = mixtures.mix16d()
    a = mix.sample(100, seed=7)
    b = mix.sample(100, seed=7)
    np.testing.assert_array_equal(a, b)
    c = mix.sample(100, seed=8)
    assert not np.array_equal(a, c)


def test_sample_shape_and_dtype():
    mix = mixtures.mix16d()
    s = mix.sample(64, seed=0)
    assert s.shape == (64, 16) and s.dtype == np.float32


def test_sample_mean_matches_mixture_mean():
    mix = mixtures.mix1d()
    s = mix.sample(200_000, seed=3)
    want = sum(w * m[0] for w, m in zip(mix.weights, mix.means))
    assert s.mean() == pytest.approx(want, abs=0.02)


def test_sample_density_agreement():
    # Histogram of a large 1-D sample should track the analytic pdf.
    mix = mixtures.mix1d()
    s = mix.sample(100_000, seed=11)[:, 0]
    hist, edges = np.histogram(s, bins=80, range=(-6, 9), density=True)
    centers = 0.5 * (edges[:-1] + edges[1:])
    pdf = mix.pdf(centers.reshape(-1, 1))
    assert np.mean(np.abs(hist - pdf)) < 0.01


def test_pdf_positive_and_finite_16d():
    mix = mixtures.mix16d()
    s = mix.sample(500, seed=5)
    p = mix.pdf(s)
    assert np.isfinite(p).all() and (p > 0).all()


def test_by_dim_dispatch():
    assert mixtures.by_dim(1).d == 1
    assert mixtures.by_dim(16).d == 16
    assert mixtures.by_dim(7).d == 7
