"""Pin the FLOP/byte model to the constants the paper quotes (§4.1, App. A)."""

import pytest

from compile import flopmodel as fm


def test_d16_flops_constant():
    # FLOPs_16(k) ~ 81.5 k^2 (paper §4.1).
    k = 32768.0
    coeff = fm.sdkde_flops_d(k, 16) / (k * k)
    assert coeff == pytest.approx(81.5, abs=0.5)


def test_d16_flops_order_of_magnitude():
    # "on the order of 10^11 FLOPs for k = 32k" (§4.1).
    total = fm.sdkde_flops_d(32768.0, 16)
    assert 5e10 < total < 5e11


def test_d16_bytes_per_tile():
    # Paper: ~7.4e4 bytes per (64, 1024) tile at d=16.
    per_tile = 4.0 * (2 * 64 * 16 + 1024 * 16 + 64)
    assert per_tile == pytest.approx(7.4e4, rel=0.05)


def test_d16_bytes_constant():
    # Bytes_16(k) ~ 1.13 k^2 with the paper's launch parameters.
    k = 32768.0
    coeff = fm.sdkde_bytes_d(k, 16) / (k * k)
    assert coeff == pytest.approx(1.13, abs=0.03)


def test_d16_intensity():
    # I_16 ~ 72 flops/byte (§4.1).
    est = fm.sdkde_estimate_d(32768.0, 16)
    assert est.intensity == pytest.approx(72.0, abs=3.0)


def test_machine_balance():
    # A6000: 155 TFLOP/s TC peak / 770 GB/s ~ 200 flops/byte.
    assert fm.machine_balance_flops_per_byte() == pytest.approx(200.0, abs=5.0)


def test_compute_bound_regime():
    # The kernel's intensity must sit between the FP32 roof (~50) and the
    # tensor-core roof (~200): the straddling the paper describes.
    est = fm.sdkde_estimate_d(32768.0, 16)
    assert 50.0 < est.intensity < 200.0


def test_1d_flops_constant():
    # FLOPs(k) ~ 17.75 k^2 (App. A).
    k = 32768.0
    coeff = fm.sdkde_flops_1d(k) / (k * k)
    assert coeff == pytest.approx(17.75, abs=0.01)


def test_1d_flops_order_of_magnitude():
    # "on the order of 2e10 flops" for k=32k (App. A).
    assert fm.sdkde_flops_1d(32768.0) == pytest.approx(2e10, rel=0.1)


def test_1d_intensity_scaling():
    # I(k) ~ 3.55 k flops/byte (App. A).
    k = 65536.0
    est = fm.sdkde_estimate_1d(k)
    assert est.intensity / k == pytest.approx(3.55, abs=0.15)


def test_flops_monotone_in_d():
    k = 1024.0
    vals = [fm.sdkde_flops_d(k, d) for d in (1, 4, 16, 32)]
    assert vals == sorted(vals)


def test_utilization():
    # 1e12 flops in 0.1 s on a 100 TFLOP/s machine = 10% utilization.
    assert fm.utilization(1e12, 0.1, 1e14) == pytest.approx(0.10)
    with pytest.raises(ValueError):
        fm.utilization(1.0, 0.0, 1.0)


def test_explicit_n_test_override():
    k = 1000.0
    default = fm.sdkde_flops_d(k, 16)
    explicit = fm.sdkde_flops_d(k, 16, n_test=k / 8.0)
    assert default == explicit
    bigger = fm.sdkde_flops_d(k, 16, n_test=k)
    assert bigger > default
