"""Oracle self-consistency: the pure-jnp references must be right first.

Everything else in the stack (Pallas kernels, Rust natives, runtime
round-trips) is validated against ref.py, so these tests pin ref.py to
closed-form ground truth where it exists.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref


def test_sq_dists_matches_bruteforce(rng):
    a = jnp.asarray(rng.normal(size=(17, 5)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(9, 5)), jnp.float32)
    got = np.asarray(ref.sq_dists(a, b))
    want = np.sum(
        (np.asarray(a)[:, None, :] - np.asarray(b)[None, :, :]) ** 2, axis=2
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sq_dists_nonnegative_on_duplicates():
    # Cancellation in ||a||^2+||b||^2-2ab^T can go slightly negative; the
    # clamp must hold even for identical points at large magnitude.
    a = jnp.full((4, 8), 1000.0, jnp.float32)
    d2 = np.asarray(ref.sq_dists(a, a))
    assert (d2 >= 0.0).all()


def test_kde_single_point_matches_gaussian_pdf():
    # KDE of one sample is exactly the kernel: closed-form check.
    x = jnp.zeros((1, 2), jnp.float32)
    w = jnp.ones(1, jnp.float32)
    y = jnp.asarray([[0.3, -0.4]], jnp.float32)  # ||y||^2 = 0.25
    h = 0.7
    got = float(ref.kde_ref(x, w, y, jnp.float32(h))[0])
    want = math.exp(-0.25 / (2 * h * h)) / ((2 * math.pi) * h * h)
    assert got == pytest.approx(want, rel=1e-5)


def test_kde_integrates_to_one_1d(rng):
    # Trapezoid integral over a wide grid ~ 1 for a compactly-spread sample.
    x = jnp.asarray(rng.normal(size=(50, 1)), jnp.float32)
    w = jnp.ones(50, jnp.float32)
    grid = jnp.linspace(-10.0, 10.0, 4001).reshape(-1, 1).astype(jnp.float32)
    pdf = np.asarray(ref.kde_ref(x, w, grid, jnp.float32(0.4)))
    integral = np.trapezoid(pdf, np.asarray(grid[:, 0]))
    assert integral == pytest.approx(1.0, abs=1e-3)


def test_laplace_integrates_to_one_1d(rng):
    # The Laplace-corrected kernel is a 4th-order kernel: still integrates
    # to 1 (the correction term integrates to 0).
    x = jnp.asarray(rng.normal(size=(50, 1)), jnp.float32)
    w = jnp.ones(50, jnp.float32)
    grid = jnp.linspace(-12.0, 12.0, 6001).reshape(-1, 1).astype(jnp.float32)
    pdf = np.asarray(ref.laplace_ref(x, w, grid, jnp.float32(0.4)))
    integral = np.trapezoid(pdf, np.asarray(grid[:, 0]))
    assert integral == pytest.approx(1.0, abs=1e-3)


def test_score_matches_autodiff_gradient(rng):
    # The empirical score must equal grad(log p_hat) of the same-bandwidth
    # KDE evaluated at the sample points.  Autodiff is the ground truth.
    import jax

    x = jnp.asarray(rng.normal(size=(40, 3)), jnp.float32)
    w = jnp.ones(40, jnp.float32)
    h_s = jnp.float32(0.9)

    def log_pdf(pt):
        return jnp.log(ref.kde_ref(x, w, pt.reshape(1, -1), h_s)[0])

    want = np.stack([np.asarray(jax.grad(log_pdf)(x[i])) for i in range(10)])
    got = np.asarray(ref.score_ref(x, w, h_s))[:10]
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-4)


def test_score_of_exact_gaussian_kernel_center():
    # Single training point at mu: score at any x is -(x - mu)/h_s^2.
    mu = jnp.asarray([[1.0, -2.0]], jnp.float32)
    w = jnp.ones(1, jnp.float32)
    h_s = 0.6
    got = np.asarray(ref.score_ref(mu, w, jnp.float32(h_s)))
    # At the sample itself the score is 0 (x == mu).
    np.testing.assert_allclose(got, np.zeros((1, 2)), atol=1e-6)


def test_debias_default_uses_hs_h_over_sqrt2(rng):
    x = jnp.asarray(rng.normal(size=(30, 2)), jnp.float32)
    w = jnp.ones(30, jnp.float32)
    h = jnp.float32(0.8)
    auto = np.asarray(ref.debias_ref(x, w, h))
    manual = np.asarray(ref.debias_ref(x, w, h, h / math.sqrt(2.0)))
    np.testing.assert_allclose(auto, manual, rtol=1e-6)


def test_laplace_factor_sign_structure():
    # Factor is positive near zero distance and negative far away: the
    # signed-tail behaviour §5 warns about.
    h, d = 1.0, 4
    near = float(ref.laplace_factor(jnp.float32(0.0), h, d))
    far = float(ref.laplace_factor(jnp.float32(100.0), h, d))
    assert near == pytest.approx(1.0 + d / 2.0)
    assert far < 0.0


def test_laplace_reduces_bias_vs_kde_on_smooth_density(rng):
    # On a standard normal with a moderately large bandwidth the
    # leading-order bias dominates; the corrected estimator must be closer
    # to the true density on average (the paper's whole point).
    n = 4000
    x = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    w = jnp.ones(n, jnp.float32)
    h = jnp.float32(0.45)
    grid = jnp.linspace(-3.0, 3.0, 241).reshape(-1, 1).astype(jnp.float32)
    true = np.exp(-np.asarray(grid[:, 0]) ** 2 / 2) / math.sqrt(2 * math.pi)
    err_kde = np.mean((np.asarray(ref.kde_ref(x, w, grid, h)) - true) ** 2)
    err_lc = np.mean((np.asarray(ref.laplace_ref(x, w, grid, h)) - true) ** 2)
    assert err_lc < err_kde


def test_sdkde_reduces_bias_vs_kde_on_smooth_density(rng):
    n = 4000
    x = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    w = jnp.ones(n, jnp.float32)
    h = jnp.float32(0.45)
    grid = jnp.linspace(-3.0, 3.0, 241).reshape(-1, 1).astype(jnp.float32)
    true = np.exp(-np.asarray(grid[:, 0]) ** 2 / 2) / math.sqrt(2 * math.pi)
    err_kde = np.mean((np.asarray(ref.kde_ref(x, w, grid, h)) - true) ** 2)
    err_sd = np.mean((np.asarray(ref.sdkde_ref(x, w, grid, h)) - true) ** 2)
    assert err_sd < err_kde


def test_sdkde_preserves_nonnegativity(rng):
    # SD-KDE is a KDE of shifted samples: nonnegative by construction,
    # unlike the Laplace correction.
    x = jnp.asarray(rng.normal(size=(100, 1)), jnp.float32)
    w = jnp.ones(100, jnp.float32)
    grid = jnp.linspace(-8.0, 8.0, 501).reshape(-1, 1).astype(jnp.float32)
    pdf = np.asarray(ref.sdkde_ref(x, w, grid, jnp.float32(0.3)))
    assert (pdf >= 0.0).all()


def test_negative_mass_zero_for_nonnegative_estimator():
    pdf = jnp.asarray([0.1, 0.0, 0.5], jnp.float32)
    true = jnp.asarray([0.2, 0.2, 0.2], jnp.float32)
    assert float(ref.negative_mass_ref(pdf, true)) == 0.0


def test_negative_mass_positive_for_signed_estimator():
    pdf = jnp.asarray([0.1, -0.05, 0.5], jnp.float32)
    true = jnp.asarray([0.2, 0.2, 0.2], jnp.float32)
    assert float(ref.negative_mass_ref(pdf, true)) > 0.0
