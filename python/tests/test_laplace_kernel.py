"""Fused / non-fused Laplace-corrected KDE kernels vs the oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import TileConfig, laplace_fused, laplace_nonfused
from compile.kernels import ref
from .conftest import make_problem


def test_fused_matches_ref_16d(problem_16d):
    x, w, y, h = problem_16d
    np.testing.assert_allclose(
        np.asarray(laplace_fused(x, w, y, h)),
        np.asarray(ref.laplace_ref(x, w, y, h)),
        rtol=5e-4, atol=1e-8,
    )


def test_nonfused_matches_ref_16d(problem_16d):
    x, w, y, h = problem_16d
    np.testing.assert_allclose(
        np.asarray(laplace_nonfused(x, w, y, h)),
        np.asarray(ref.laplace_ref(x, w, y, h)),
        rtol=5e-4, atol=1e-8,
    )


def test_fusion_is_estimator_invariant(problem_1d):
    # Fig. 2's observation: the fused curve overlaps the non-fused one —
    # fusion is an implementation optimization, not an estimator change.
    x, w, y, h = problem_1d
    np.testing.assert_allclose(
        np.asarray(laplace_fused(x, w, y, h)),
        np.asarray(laplace_nonfused(x, w, y, h)),
        rtol=1e-5, atol=1e-9,
    )


@pytest.mark.parametrize("n,m,d", [(70, 20, 1), (128, 16, 4), (200, 55, 16)])
def test_shapes_sweep(rng, n, m, d):
    x, w, y, h = make_problem(rng, n, m, d)
    np.testing.assert_allclose(
        np.asarray(laplace_fused(x, w, y, h)),
        np.asarray(ref.laplace_ref(x, w, y, h)),
        rtol=5e-4, atol=1e-7,
    )


def test_masking(rng):
    x, w, y, h = make_problem(rng, 144, 24, d=2)
    keep = 101
    w_mask = jnp.asarray(
        np.concatenate([np.ones(keep), np.zeros(144 - keep)]), jnp.float32
    )
    got = np.asarray(laplace_fused(x, w_mask, y, h))
    want = np.asarray(
        ref.laplace_ref(x[:keep], jnp.ones(keep, jnp.float32), y, h)
    )
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-8)


def test_signed_tail_goes_negative(rng):
    # Far queries sit in the negative lobe of the 4th-order kernel: the
    # estimator must actually produce negative values there (§5 caveat).
    x = jnp.zeros((16, 1), jnp.float32)
    w = jnp.ones(16, jnp.float32)
    y = jnp.asarray([[2.5]], jnp.float32)  # ||u||/h = 2.5 > sqrt(2 + d)
    h = jnp.float32(1.0)
    val = float(laplace_fused(x, w, y, h)[0])
    assert val < 0.0


def test_tiles_invariant(rng):
    x, w, y, h = make_problem(rng, 160, 40, d=8)
    base = np.asarray(ref.laplace_ref(x, w, y, h))
    for bm, bn in [(8, 32), (32, 128), (64, 64)]:
        got = np.asarray(laplace_fused(x, w, y, h, tiles=TileConfig(bm, bn)))
        np.testing.assert_allclose(got, base, rtol=5e-4, atol=1e-8)
