"""Shared fixtures for the Flash-SD-KDE python test suite."""

import numpy as np
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def make_problem(rng, n, m, d, h=0.8, spread=2.0):
    """Random (x, w, y, h) problem with full weights, f32."""
    x = jnp.asarray(rng.normal(scale=spread, size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(scale=spread, size=(m, d)), jnp.float32)
    w = jnp.ones(n, jnp.float32)
    return x, w, y, jnp.float32(h)


@pytest.fixture
def problem_16d(rng):
    return make_problem(rng, n=192, m=56, d=16)


@pytest.fixture
def problem_1d(rng):
    return make_problem(rng, n=300, m=44, d=1, h=0.35)
