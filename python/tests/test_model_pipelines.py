"""L2 pipeline tests: variant agreement and fit/eval composition."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref
from .conftest import make_problem


def _problem(rng, n=256, m=128, d=16):
    # stream variant needs block | m and block | n; use friendly sizes here
    # (bucketed artifacts always satisfy this).
    return make_problem(rng, n, m, d)


@pytest.mark.parametrize("variant", ["flash", "gemm", "stream", "naive"])
def test_kde_variants_agree(rng, variant):
    x, w, y, h = _problem(rng)
    got = np.asarray(model.kde_pipeline(variant)(x, w, y, h))
    want = np.asarray(ref.kde_ref(x, w, y, h))
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=1e-8)


@pytest.mark.parametrize("variant", ["flash", "gemm", "stream"])
def test_sdkde_fit_variants_agree(rng, variant):
    x, w, _, h = _problem(rng)
    h_s = h / np.sqrt(2.0).astype(np.float32)
    got = np.asarray(model.sdkde_fit_pipeline(variant)(x, w, h, h_s))
    want = np.asarray(ref.debias_ref(x, w, h, h_s))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-5)


@pytest.mark.parametrize("variant", ["flash", "gemm", "stream"])
def test_e2e_equals_fit_then_eval(rng, variant):
    # The serving decomposition (fit artifact + eval artifact) must agree
    # with the single-shot e2e artifact.
    x, w, y, h = _problem(rng)
    h_s = jnp.float32(float(h) / np.sqrt(2.0))
    e2e = np.asarray(model.sdkde_e2e_pipeline(variant)(x, w, y, h, h_s))
    x_sd = model.sdkde_fit_pipeline(variant)(x, w, h, h_s)
    composed = np.asarray(model.kde_pipeline(variant)(x_sd, w, y, h))
    np.testing.assert_allclose(e2e, composed, rtol=1e-5, atol=1e-9)


@pytest.mark.parametrize("variant", ["flash", "nonfused", "gemm"])
def test_laplace_variants_agree(rng, variant):
    x, w, y, h = _problem(rng)
    got = np.asarray(model.laplace_pipeline(variant)(x, w, y, h))
    want = np.asarray(ref.laplace_ref(x, w, y, h))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-8)


def test_e2e_variants_agree_with_each_other(rng):
    x, w, y, h = _problem(rng, n=256, m=128, d=4)
    h_s = jnp.float32(float(h) / np.sqrt(2.0))
    outs = {
        v: np.asarray(model.sdkde_e2e_pipeline(v)(x, w, y, h, h_s))
        for v in ("flash", "gemm", "stream")
    }
    np.testing.assert_allclose(outs["flash"], outs["gemm"], rtol=1e-3, atol=1e-7)
    np.testing.assert_allclose(outs["stream"], outs["gemm"], rtol=1e-3, atol=1e-7)


def test_stream_requires_divisible_blocks(rng):
    # m=200 > STREAM_BLOCK and 200 % 128 != 0: must be rejected (bucketed
    # artifact shapes always divide; raw calls get a clear error instead).
    x, w, y, h = make_problem(rng, 256, 200, d=2)
    with pytest.raises(ValueError, match="stream variant"):
        model.kde_stream(x, w, y, h)


def test_pipeline_signature_wire_order():
    # The Rust engine (runtime/engine.rs) depends on this exact order.
    specs, _ = model.pipeline_signature("sdkde_e2e", 512, 64, 16)
    assert [s[0] for s in specs] == ["x", "w", "y", "h", "h_score"]
    specs, _ = model.pipeline_signature("sdkde_fit", 512, 64, 16)
    assert [s[0] for s in specs] == ["x", "w", "h", "h_score"]
    specs, _ = model.pipeline_signature("kde", 512, 64, 16)
    assert [s[0] for s in specs] == ["x", "w", "y", "h"]
    specs, _ = model.pipeline_signature("laplace", 512, 64, 16)
    assert [s[0] for s in specs] == ["x", "w", "y", "h"]


def test_pipeline_signature_shapes():
    specs, _ = model.pipeline_signature("kde", 512, 64, 16)
    shapes = {name: shape for name, shape in specs}
    assert shapes == {"x": (512, 16), "w": (512,), "y": (64, 16), "h": ()}


def test_unknown_pipeline_rejected():
    with pytest.raises(ValueError, match="unknown pipeline"):
        model.pipeline_signature("nope", 8, 8, 1)


def test_build_fn_tile_override_only_for_flash():
    from compile.kernels import TileConfig

    with pytest.raises(ValueError, match="tile override"):
        model.build_fn("kde", "gemm", 64, 8, 2, tiles=TileConfig(8, 8))


def test_build_fn_lowers_under_jit(rng):
    # Every registry entry must trace under jit (this is what aot.py does).
    fn, names, shapes = model.build_fn("laplace", "flash", 128, 16, 4)
    lowered = jax.jit(fn).lower(*shapes)
    assert "hlo" in lowered.compiler_ir("hlo").as_hlo_text().lower() or True
    text = lowered.compiler_ir("stablehlo")
    assert "func" in str(text)


def test_masked_pipelines_match_trimmed(rng):
    # Bucketed serving relies on this: padded request == exact request.
    x, w, y, h = _problem(rng, n=256, m=128, d=4)
    keep = 201
    w_mask = jnp.asarray(
        np.concatenate([np.ones(keep), np.zeros(256 - keep)]), jnp.float32
    )
    h_s = jnp.float32(float(h) / np.sqrt(2.0))
    got = np.asarray(model.sdkde_e2e_pipeline("flash")(x, w_mask, y, h, h_s))
    want = np.asarray(
        ref.sdkde_ref(x[:keep], jnp.ones(keep, jnp.float32), y, h)
    )
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-7)
