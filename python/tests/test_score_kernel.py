"""Flash score kernel (the paper's dominant cost) vs the oracle."""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import TileConfig, debias, score, score_sums
from compile.kernels import ref
from .conftest import make_problem


def test_score_matches_ref_16d(problem_16d):
    x, w, _, h = problem_16d
    h_s = h / math.sqrt(2.0)
    np.testing.assert_allclose(
        np.asarray(score(x, w, h_s)),
        np.asarray(ref.score_ref(x, w, h_s)),
        rtol=5e-4, atol=1e-5,
    )


def test_score_matches_ref_1d(problem_1d):
    x, w, _, h = problem_1d
    np.testing.assert_allclose(
        np.asarray(score(x, w, h)),
        np.asarray(ref.score_ref(x, w, h)),
        rtol=5e-4, atol=1e-5,
    )


def test_score_sums_decomposition(rng):
    # denom/numer are exactly the Phi row-sum and T = Phi X rows (§4).
    x, w, _, h = make_problem(rng, 150, 1, d=5)
    denom, numer = score_sums(x, w, h)
    phi = np.asarray(ref.gaussian_matrix(x, x, h)) * np.asarray(w)[None, :]
    np.testing.assert_allclose(np.asarray(denom), phi.sum(1), rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(numer), phi @ np.asarray(x), rtol=2e-5, atol=1e-5
    )


@pytest.mark.parametrize("n", [33, 64, 100, 257, 512])
def test_non_divisible_sizes(rng, n):
    x, w, _, h = make_problem(rng, n, 1, d=3)
    np.testing.assert_allclose(
        np.asarray(score(x, w, h)),
        np.asarray(ref.score_ref(x, w, h)),
        rtol=5e-4, atol=1e-5,
    )


@pytest.mark.parametrize("bm,bn", [(8, 16), (32, 32), (64, 128)])
def test_tiles_do_not_change_score(rng, bm, bn):
    x, w, _, h = make_problem(rng, 140, 1, d=4)
    np.testing.assert_allclose(
        np.asarray(score(x, w, h, tiles=TileConfig(bm, bn))),
        np.asarray(ref.score_ref(x, w, h)),
        rtol=5e-4, atol=1e-5,
    )


def test_debias_matches_ref(problem_16d):
    x, w, _, h = problem_16d
    np.testing.assert_allclose(
        np.asarray(debias(x, w, h)),
        np.asarray(ref.debias_ref(x, w, h)),
        rtol=5e-4, atol=1e-5,
    )


def test_debias_explicit_score_bandwidth(rng):
    x, w, _, h = make_problem(rng, 90, 1, d=2)
    h_s = jnp.float32(0.5)
    np.testing.assert_allclose(
        np.asarray(debias(x, w, h, h_s)),
        np.asarray(ref.debias_ref(x, w, h, h_s)),
        rtol=5e-4, atol=1e-5,
    )


def test_debias_masked_rows_pass_through(rng):
    # Padding rows (w=0) must come out of the fit unchanged so the eval
    # kernels downstream see finite, inert values.
    x, w, _, h = make_problem(rng, 128, 1, d=4)
    keep = 70
    w_mask = jnp.asarray(
        np.concatenate([np.ones(keep), np.zeros(128 - keep)]), jnp.float32
    )
    out = np.asarray(debias(x, w_mask, h))
    np.testing.assert_array_equal(out[keep:], np.asarray(x)[keep:])
    # Valid rows must match a trimmed unmasked fit.
    want = np.asarray(
        debias(x[:keep], jnp.ones(keep, jnp.float32), h)
    )
    np.testing.assert_allclose(out[:keep], want, rtol=5e-4, atol=1e-5)


def test_debias_shift_shrinks_with_bandwidth(rng):
    # The shift is O(h^2): halving h must shrink the mean shift ~4x on a
    # smooth sample (loose factor accounts for the score's own h-dependence).
    x, w, _, _ = make_problem(rng, 400, 1, d=1, spread=1.0)
    shift_big = np.abs(np.asarray(debias(x, w, jnp.float32(0.4))) - np.asarray(x)).mean()
    shift_small = np.abs(np.asarray(debias(x, w, jnp.float32(0.2))) - np.asarray(x)).mean()
    assert shift_small < shift_big / 2.0


def test_score_points_toward_density_mode(rng):
    # For a unimodal sample the score field must point toward the mode:
    # negative correlation between position and score.
    x, w, _, _ = make_problem(rng, 600, 1, d=1, spread=1.0)
    s = np.asarray(score(x, w, jnp.float32(0.35)))[:, 0]
    pos = np.asarray(x)[:, 0]
    corr = np.corrcoef(pos, s)[0, 1]
    assert corr < -0.8
