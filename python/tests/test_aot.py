"""AOT driver tests: plan, manifest schema, freshness, HLO round-trip."""

import json
import os

import pytest

from compile import aot


def test_plan_quick_subset_of_full():
    quick = {aot.entry_filename(e) for e in aot.plan_entries(quick=True)}
    full = {aot.entry_filename(e) for e in aot.plan_entries(quick=False)}
    assert quick <= full
    assert len(quick) < len(full)


def test_plan_filenames_unique():
    entries = aot.plan_entries(quick=False, sweep=True)
    names = [aot.entry_filename(e) for e in entries]
    assert len(names) == len(set(names))


def test_plan_covers_every_paper_experiment():
    entries = aot.plan_entries(quick=False, sweep=True)
    key = {(e["pipeline"], e["variant"], e["d"]) for e in entries}
    # Fig. 1 / Fig. 6: e2e flash vs gemm in both dims.
    assert ("sdkde_e2e", "flash", 16) in key
    assert ("sdkde_e2e", "gemm", 16) in key
    assert ("sdkde_e2e", "flash", 1) in key
    # Table 1: stream (KeOps analogue) variants.
    assert ("kde", "stream", 16) in key
    assert ("sdkde_e2e", "stream", 16) in key
    # Fig. 4: fused vs non-fused Laplace in 1-D.
    assert ("laplace", "flash", 1) in key
    assert ("laplace", "nonfused", 1) in key
    # §6.2 sweep artifacts carry tile overrides.
    assert any(e["tiles"] for e in entries)


def test_plan_serving_buckets_present():
    entries = aot.plan_entries(quick=False, sweep=False)
    ms = {e["m"] for e in entries if e["pipeline"] == "kde"
          and e["variant"] == "flash" and e["d"] == 16}
    for m in aot.SERVING_M:
        assert m in ms


def test_naive_capped():
    entries = aot.plan_entries(quick=False, sweep=False)
    naive_n = [e["n"] for e in entries if e["variant"] == "naive"]
    assert naive_n and max(naive_n) <= aot.NAIVE_MAX_N


def test_entry_filename_encodes_tiles():
    e = {"pipeline": "sdkde_fit", "variant": "flash", "d": 16, "n": 2048,
         "m": 256, "tiles": [64, 512]}
    assert aot.entry_filename(e) == (
        "sdkde_fit__flash__d16__n2048__m256__bm64__bn512.hlo.txt"
    )


def test_digest_changes_with_plan():
    a = aot.plan_digest(aot.plan_entries(quick=True))
    b = aot.plan_digest(aot.plan_entries(quick=False))
    assert a != b


def test_lower_entry_produces_parseable_hlo():
    e = {"pipeline": "kde", "variant": "gemm", "d": 2, "n": 64, "m": 8,
         "tiles": None}
    text, inputs, outputs = aot.lower_entry(e)
    assert "ENTRY" in text and "HloModule" in text
    assert [i["name"] for i in inputs] == ["x", "w", "y", "h"]
    assert inputs[0]["shape"] == [64, 2]
    assert outputs == [{"shape": [8]}]


def test_build_artifacts_writes_and_skips(tmp_path, monkeypatch, capsys):
    # Shrink the quick plan to two tiny entries to keep this test fast.
    tiny = [
        {"pipeline": "kde", "variant": "gemm", "d": 1, "n": 32, "m": 8,
         "tiles": None},
        {"pipeline": "laplace", "variant": "gemm", "d": 1, "n": 32, "m": 8,
         "tiles": None},
    ]
    monkeypatch.setattr(aot, "plan_entries", lambda quick, sweep: tiny)
    out = str(tmp_path)
    man = aot.build_artifacts(out, quick=True, sweep=False)
    assert len(man["entries"]) == 2
    for e in man["entries"]:
        assert os.path.exists(os.path.join(out, e["file"]))
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["digest"] == man["digest"]

    # Second build must be a freshness no-op.
    capsys.readouterr()
    aot.build_artifacts(out, quick=True, sweep=False)
    assert "up to date" in capsys.readouterr().out


def test_build_artifacts_rebuilds_on_missing_file(tmp_path, monkeypatch):
    tiny = [{"pipeline": "kde", "variant": "gemm", "d": 1, "n": 32, "m": 8,
             "tiles": None}]
    monkeypatch.setattr(aot, "plan_entries", lambda quick, sweep: tiny)
    out = str(tmp_path)
    man = aot.build_artifacts(out, quick=True, sweep=False, verbose=False)
    target = os.path.join(out, man["entries"][0]["file"])
    os.remove(target)
    aot.build_artifacts(out, quick=True, sweep=False, verbose=False)
    assert os.path.exists(target)
