"""Gradient-serving kernel (score at arbitrary queries) vs oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import TileConfig, score, score_at
from compile.kernels import ref
from compile.model import build_fn, score_eval_pipeline
from .conftest import make_problem


def test_matches_ref(rng):
    x, w, y, h = make_problem(rng, 180, 40, d=8)
    np.testing.assert_allclose(
        np.asarray(score_at(x, w, y, h)),
        np.asarray(ref.score_at_ref(x, w, y, h)),
        rtol=5e-4, atol=1e-5,
    )


def test_self_queries_reduce_to_train_score(rng):
    # score_at(X, X) must equal the train-train score (self-term included).
    x, w, _, h = make_problem(rng, 120, 1, d=3)
    np.testing.assert_allclose(
        np.asarray(score_at(x, w, x, h)),
        np.asarray(score(x, w, h)),
        rtol=1e-5, atol=1e-7,
    )


def test_matches_autodiff_gradient(rng):
    # The served gradient IS grad log p_hat: autodiff is ground truth.
    x, w, y, h = make_problem(rng, 60, 6, d=2)

    def log_pdf(pt):
        return jnp.log(ref.kde_ref(x, w, pt.reshape(1, -1), h)[0])

    want = np.stack([np.asarray(jax.grad(log_pdf)(y[i])) for i in range(6)])
    got = np.asarray(score_at(x, w, y, h))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=1e-4)


def test_far_query_guarded(rng):
    # Queries far outside the data: denominator underflows; the guarded
    # division must return finite values, not NaN/inf.
    x, w, _, h = make_problem(rng, 50, 1, d=2, h=0.3)
    y_far = jnp.full((3, 2), 1e4, jnp.float32)
    out = np.asarray(score_at(x, w, y_far, h))
    assert np.isfinite(out).all()


def test_masking(rng):
    x, w, y, h = make_problem(rng, 140, 20, d=4)
    keep = 93
    w_mask = jnp.asarray(
        np.concatenate([np.ones(keep), np.zeros(140 - keep)]), jnp.float32
    )
    got = np.asarray(score_at(x, w_mask, y, h))
    want = np.asarray(
        ref.score_at_ref(x[:keep], jnp.ones(keep, jnp.float32), y, h)
    )
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_tiles_invariant(rng):
    x, w, y, h = make_problem(rng, 100, 30, d=2)
    base = np.asarray(ref.score_at_ref(x, w, y, h))
    for bm, bn in [(8, 32), (64, 64)]:
        got = np.asarray(score_at(x, w, y, h, tiles=TileConfig(bm, bn)))
        np.testing.assert_allclose(got, base, rtol=5e-4, atol=1e-5)


@pytest.mark.parametrize("variant", ["flash", "gemm"])
def test_pipeline_variants_agree(rng, variant):
    x, w, y, h = make_problem(rng, 128, 32, d=4)
    got = np.asarray(score_eval_pipeline(variant)(x, w, y, h))
    want = np.asarray(ref.score_at_ref(x, w, y, h))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_lowering_and_signature():
    fn, names, shapes = build_fn("score_eval", "flash", 256, 64, 16)
    assert names == ["x", "w", "y", "h_score"]
    lowered = jax.jit(fn).lower(*shapes)
    assert "func" in str(lowered.compiler_ir("stablehlo"))
