"""L1 Laplace-corrected KDE kernels: fused fast path + non-fused passes.

The Laplace-corrected kernel (paper §5) removes the leading O(h^2) KDE bias
without an empirical score pass:

    K_h^LC(u) = K_h(u) * (1 + d/2 - ||u||^2 / (2 h^2))

Because the correction factor reuses the *same* scaled distances as the
plain kernel, a fused kernel applies it inside the same tile pass over the
data ("Flash-Laplace-KDE").  The non-fused variant the paper compares
against must either recompute distances in a second kernel or materialize
them; we implement the recompute flavor as a separate correction kernel so
the fused-vs-non-fused bench (Fig. 4) measures exactly the extra pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    TileConfig,
    normalizer,
    pad_rows,
    padded_sizes,
    pick_tiles,
    validate_pairwise_args,
)


def _tile_dists(y, x):
    """GEMM-form squared distances for one [BM, BN] tile."""
    y2 = jnp.sum(y * y, axis=1, keepdims=True)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    cross = jax.lax.dot_general(
        y, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return jnp.maximum(y2 + x2.T - 2.0 * cross, 0.0)


def _laplace_fused_kernel(y_ref, x_ref, w_ref, h_ref, o_ref):
    """Fused tile: o[i] += sum_j w_j phi_ij (1 + d/2 - d2/(2h^2)).

    One distance computation, one exp, and the affine Laplace factor applied
    in-register — the "kernel fusion opportunity" of §5.
    """
    j = pl.program_id(1)
    y = y_ref[...]
    x = x_ref[...]
    w = w_ref[...]
    h = h_ref[0, 0]
    d = y.shape[1]

    d2 = _tile_dists(y, x)
    inv2h2 = 1.0 / (2.0 * h * h)
    phi = jnp.exp(-d2 * inv2h2)
    factor = (1.0 + 0.5 * d) - d2 * inv2h2
    partial = jnp.sum(phi * factor * w[None, :], axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


def _laplace_corr_kernel(y_ref, x_ref, w_ref, h_ref, o_ref):
    """Non-fused second pass: recomputes distances, accumulates only the
    correction term  sum_j w_j phi_ij (d/2 - d2/(2h^2)).

    Added to a plain KDE pass this reconstructs the fused result; the
    deliberate distance recomputation models the paper's non-fused baseline.
    """
    j = pl.program_id(1)
    y = y_ref[...]
    x = x_ref[...]
    w = w_ref[...]
    h = h_ref[0, 0]
    d = y.shape[1]

    d2 = _tile_dists(y, x)
    inv2h2 = 1.0 / (2.0 * h * h)
    phi = jnp.exp(-d2 * inv2h2)
    corr = (0.5 * d) - d2 * inv2h2
    partial = jnp.sum(phi * corr * w[None, :], axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


def _run_pairwise(kernel, x, w, y, h, tiles):
    """Shared pallas_call wiring for the two Laplace kernels."""
    validate_pairwise_args(x, w, y)
    m, n = y.shape[0], x.shape[0]
    cfg = pick_tiles(m, n, tiles, d=x.shape[1])
    mp, np_ = padded_sizes(m, n, cfg)

    y_p = pad_rows(y, mp)
    x_p = pad_rows(x, np_)
    w_p = pad_rows(w, np_)
    h_arr = jnp.asarray(h, jnp.float32).reshape(1, 1)

    d = x.shape[1]
    out = pl.pallas_call(
        kernel,
        grid=cfg.grid(mp, np_),
        in_specs=[
            pl.BlockSpec((cfg.block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((cfg.block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((cfg.block_n,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((cfg.block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=True,
    )(y_p, x_p, w_p, h_arr)
    return out[:m]


def laplace_fused_raw(x, w, y, h, *, tiles: TileConfig | None = None):
    """Unnormalized fused Flash-Laplace-KDE sums, [m]."""
    return _run_pairwise(_laplace_fused_kernel, x, w, y, h, tiles)


def laplace_correction_raw(x, w, y, h, *, tiles: TileConfig | None = None):
    """Unnormalized correction-only sums (non-fused second pass), [m]."""
    return _run_pairwise(_laplace_corr_kernel, x, w, y, h, tiles)


def laplace_fused(x, w, y, h, *, tiles: TileConfig | None = None):
    """Fused Flash-Laplace-KDE density at Y, [m] (may be negative)."""
    d = x.shape[1]
    count = jnp.sum(w)
    raw = laplace_fused_raw(x, w, y, h, tiles=tiles)
    return raw * normalizer(h, d) / count


def laplace_nonfused(x, w, y, h, *, tiles: TileConfig | None = None):
    """Non-fused Laplace-corrected KDE: plain KDE pass + correction pass.

    Two full tile sweeps over the data (distances computed twice), matching
    the paper's non-fused baseline in Fig. 4.
    """
    from .kde import kde_raw  # local import to avoid a cycle

    d = x.shape[1]
    count = jnp.sum(w)
    raw = kde_raw(x, w, y, h, tiles=tiles) + laplace_correction_raw(
        x, w, y, h, tiles=tiles
    )
    return raw * normalizer(h, d) / count
