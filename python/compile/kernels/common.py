"""Shared tiling/padding utilities for the Flash-SD-KDE Pallas kernels.

The paper (§4, §6.2) tiles every pairwise interaction into BLOCK_M x BLOCK_N
tiles streamed through the matrix unit with streaming accumulation, so the
full n_train x n_train / n_train x n_test interaction matrices are never
materialized.  These helpers centralize the tile-size policy, the grid
construction, and the scalar-operand plumbing shared by the KDE, score and
Laplace kernels.

All kernels run under ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.  The BlockSpec structure is
still the real deliverable — it is the TPU analogue of the paper's Triton
launch parameters (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

# Default tile sizes.  The paper's best configuration on the A6000 was
# BLOCK_M=64, BLOCK_N=1024 (§6.2); on the MXU the natural tiles are
# multiples of (8, 128) for f32.  The perf pass re-tuned these from the
# §6.2 BlockSpec sweep (EXPERIMENTS.md §Perf): (256, 512) minimizes grid
# steps (the dominant interpret/CPU overhead and, on a real TPU, the
# per-step DMA issue cost) while staying ~67 KiB of VMEM — far below the
# ~16 MiB/core budget.  Small problems clamp to power-of-two tiles.
DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 512

# Dimensions we officially support (paper focuses on d=16; d=1 appendix).
SUPPORTED_DIMS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Tile configuration for a pairwise kernel.

    ``block_m`` tiles the *output* rows (queries for KDE, train points for
    the score), ``block_n`` tiles the reduction dimension (train points).
    The paper sweeps BLOCK_M in {32..256} and BLOCK_N in {32..1024}; the
    ablation bench sweeps the same space here.
    """

    block_m: int = DEFAULT_BLOCK_M
    block_n: int = DEFAULT_BLOCK_N

    def __post_init__(self):
        if self.block_m <= 0 or self.block_n <= 0:
            raise ValueError(f"tile sizes must be positive, got {self}")

    def clamp(self, m: int, n: int) -> "TileConfig":
        """Shrink tiles to the problem size so tiny problems still lower.

        Clamped sizes are floored to powers of two so that any two tile
        extents divide a common power-of-two padding target (score kernels
        pad one array for both the output-row and reduction-row roles).
        """
        return TileConfig(
            block_m=_pow2_floor(min(self.block_m, m)),
            block_n=_pow2_floor(min(self.block_n, n)),
        )

    def grid(self, m: int, n: int) -> tuple[int, int]:
        """Grid dimensions (output tiles, reduction tiles).

        Both extents must divide exactly; callers pad first (pad_rows).
        """
        if m % self.block_m != 0:
            raise ValueError(f"m={m} not divisible by block_m={self.block_m}")
        if n % self.block_n != 0:
            raise ValueError(f"n={n} not divisible by block_n={self.block_n}")
        return (m // self.block_m, n // self.block_n)

    def vmem_bytes(self, d: int) -> int:
        """Estimated VMEM working set per grid step, bytes (f32).

        Mirrors the paper's tile-byte model (§4.1): one query block
        [BM, d], one streamed train block [BN, d] (+ weights [BN]), and the
        accumulator [BM, d+1].  Used by the analysis layer to bound block
        sizes against the ~16 MiB/core VMEM budget.
        """
        return 4 * (
            self.block_m * d          # output-row block
            + self.block_n * d        # streamed train block
            + self.block_n            # train weights
            + self.block_m * (d + 1)  # accumulator (numer + denom / pdf)
        )


def _pow2_floor(x: int) -> int:
    """Largest power of two <= x (x >= 1)."""
    if x < 1:
        raise ValueError(f"tile extent must be >= 1, got {x}")
    return 1 << (x.bit_length() - 1)


def round_up(x: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= x."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return ((x + multiple - 1) // multiple) * multiple


def pad_rows(arr, target_rows: int, value: float = 0.0):
    """Pad a [n, ...] array with constant rows up to target_rows."""
    n = arr.shape[0]
    if n > target_rows:
        raise ValueError(f"cannot pad {n} rows down to {target_rows}")
    if n == target_rows:
        return arr
    pad_width = [(0, target_rows - n)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad_width, constant_values=value)


def pick_tiles(
    m: int, n: int, cfg: TileConfig | None = None, d: int | None = None
) -> TileConfig:
    """Resolve a tile config for an (m output rows, n reduction rows) problem.

    Shrinks the default tiles for small problems and validates divisibility
    after the caller pads with :func:`padded_sizes`.  When no explicit config
    is given the default is dimension-aware (perf pass, EXPERIMENTS.md §Perf):
    in 1-D the elementwise tile work dominates and a smaller output block
    wins; in high-d the matmul amortizes a taller block.
    """
    if cfg is None:
        cfg = TileConfig(128, 512) if d == 1 else TileConfig()
    return cfg.clamp(m, n)


def padded_sizes(m: int, n: int, cfg: TileConfig) -> tuple[int, int]:
    """Row counts after padding so the grid divides exactly."""
    return round_up(m, cfg.block_m), round_up(n, cfg.block_n)


def gaussian_log_norm(d: int):
    """log of the Gaussian normalizer (2*pi)^{d/2}; h^d handled separately."""
    return 0.5 * d * math.log(2.0 * math.pi)


def normalizer(h, d: int):
    """1 / ((2*pi)^{d/2} h^d) as a traced jnp expression (h is a tracer)."""
    return jnp.exp(-gaussian_log_norm(d)) / (h ** d)


def validate_pairwise_args(x, w, y, *, d_axis: int = 1) -> None:
    """Shape sanity checks shared by kernel wrappers (raises ValueError)."""
    if x.ndim != 2:
        raise ValueError(f"X must be [n, d], got shape {x.shape}")
    if y.ndim != 2:
        raise ValueError(f"Y must be [m, d], got shape {y.shape}")
    if x.shape[d_axis] != y.shape[d_axis]:
        raise ValueError(
            f"dimension mismatch: X has d={x.shape[d_axis]}, Y has d={y.shape[d_axis]}"
        )
    if w.ndim != 1 or w.shape[0] != x.shape[0]:
        raise ValueError(
            f"weights must be [n={x.shape[0]}], got shape {w.shape}"
        )
