"""Flash-SD-KDE L1 Pallas kernels (build-time only; never on request path).

Exports the streaming tiled kernels (flash KDE, flash score, fused Laplace)
and their pure-jnp oracles.  See DESIGN.md §2 for how the BlockSpec tiling
maps the paper's Triton/Tensor-Core formulation onto the TPU model.
"""

from .common import TileConfig, DEFAULT_BLOCK_M, DEFAULT_BLOCK_N
from .kde import kde, kde_raw, kde_with_tiles
from .laplace import laplace_fused, laplace_nonfused
from .score import debias, score, score_at, score_sums, score_sums_at

__all__ = [
    "TileConfig",
    "DEFAULT_BLOCK_M",
    "DEFAULT_BLOCK_N",
    "kde",
    "kde_raw",
    "kde_with_tiles",
    "laplace_fused",
    "laplace_nonfused",
    "debias",
    "score",
    "score_at",
    "score_sums",
    "score_sums_at",
]
