"""L1 flash score kernel: the paper's dominant cost, as streaming GEMMs.

Nsight traces in the paper (§6.2) attribute ~95% of SD-KDE runtime to the
empirical score.  The paper's reformulation (§4) turns the naive
O(n^2 d)-elementwise numerator

    sum_j -(x_i - x_j) phi_ij

into two Tensor-Core-shaped reductions via the identity

    sum_j (x_i - x_j) phi_ij = x_i * (sum_j phi_ij)  -  (Phi X)_i

so each tile needs one Gram-style matmul for the distances (X X^T) and one
[BM, BN] x [BN, d] matmul for T = Phi X.  This kernel computes, per train
point i:

    denom_i = sum_j w_j phi_ij            (phi at score bandwidth h_s)
    numer_i = sum_j w_j phi_ij x_j        ([n, d], the T = Phi X row)

with streaming accumulation over train blocks — the [n, n] matrix is never
materialized.  The score itself,

    s(x_i) = (numer_i - x_i denom_i) / (h_s^2 denom_i),

is a cheap [n, d] elementwise epilogue applied by the wrapper (XLA fuses it).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TileConfig, pad_rows, padded_sizes, pick_tiles


def _score_kernel(xi_ref, xj_ref, w_ref, h_ref, denom_ref, numer_ref):
    """One [BM, BN] tile of the train-train score pass."""
    j = pl.program_id(1)

    xi = xi_ref[...]                                  # [BM, d] output rows
    xj = xj_ref[...]                                  # [BN, d] streamed rows
    w = w_ref[...]                                    # [BN]
    h_s = h_ref[0, 0]

    # Gram-form distances: the paper's G_score = X X^T tile.
    xi2 = jnp.sum(xi * xi, axis=1, keepdims=True)     # [BM, 1]
    xj2 = jnp.sum(xj * xj, axis=1, keepdims=True)     # [BN, 1]
    cross = jax.lax.dot_general(
        xi, xj,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [BM, BN]
    d2 = jnp.maximum(xi2 + xj2.T - 2.0 * cross, 0.0)

    phi = jnp.exp(-d2 / (2.0 * h_s * h_s)) * w[None, :]

    # Second matmul: the T = Phi X tile ([BM, BN] x [BN, d]).
    t = jax.lax.dot_general(
        phi, xj,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [BM, d]
    s = jnp.sum(phi, axis=1)                          # [BM]

    @pl.when(j == 0)
    def _init():
        denom_ref[...] = jnp.zeros_like(denom_ref)
        numer_ref[...] = jnp.zeros_like(numer_ref)

    denom_ref[...] += s
    numer_ref[...] += t


def score_sums(x, w, h_s, *, tiles: TileConfig | None = None):
    """Streaming train-train score reductions: (denom [n], numer [n, d])."""
    if x.ndim != 2:
        raise ValueError(f"X must be [n, d], got {x.shape}")
    n, d = x.shape
    cfg = pick_tiles(n, n, tiles, d=d)
    n_out, n_red = padded_sizes(n, n, cfg)
    npad = max(n_out, n_red)
    # One padded copy serves both the output-row and reduction-row roles.
    x_p = pad_rows(x, npad)
    denom, numer = _score_sums_call(x_p, pad_rows(w, npad), x_p, h_s, cfg, d)
    return denom[:n], numer[:n]


def score_sums_at(x, w, y, h_s, *, tiles: TileConfig | None = None):
    """Cross-set score reductions at query rows: (denom [m], numer [m, d]).

    Same tiled kernel as the train-train pass — the output-row operand is
    simply the query block instead of a train block.  This powers the
    gradient-serving endpoint (∇ log p̂ at arbitrary points, e.g. for
    Langevin sampling over a fitted density).
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[1]:
        raise ValueError(f"X [n,d] / Y [m,d] mismatch: {x.shape} vs {y.shape}")
    m, n, d = y.shape[0], x.shape[0], x.shape[1]
    cfg = pick_tiles(m, n, tiles, d=d)
    mp, np_ = padded_sizes(m, n, cfg)
    denom, numer = _score_sums_call(
        pad_rows(y, mp), pad_rows(w, np_), pad_rows(x, np_), h_s, cfg, d
    )
    return denom[:m], numer[:m]


def _score_sums_call(rows, w_p, x_p, h_s, cfg, d):
    """Shared pallas_call: output rows `rows` against streamed set `x_p`."""
    h_arr = jnp.asarray(h_s, jnp.float32).reshape(1, 1)
    grid = cfg.grid(rows.shape[0], x_p.shape[0])
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.block_m, d), lambda i, j: (i, 0)),   # output rows
            pl.BlockSpec((cfg.block_n, d), lambda i, j: (j, 0)),   # streamed X
            pl.BlockSpec((cfg.block_n,), lambda i, j: (j,)),       # w
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),             # h_s
        ],
        out_specs=[
            pl.BlockSpec((cfg.block_m,), lambda i, j: (i,)),
            pl.BlockSpec((cfg.block_m, d), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((rows.shape[0], d), jnp.float32),
        ],
        interpret=True,
    )(rows, x_p, w_p, h_arr)


def score(x, w, h_s, *, tiles: TileConfig | None = None):
    """Empirical KDE score s(x_i) at every training point, [n, d].

    Padded rows (w=0) receive a *finite* but meaningless score (their own
    denom contribution keeps the division well-defined only if w_i=1); the
    wrapper epilogue therefore guards the division with the row's own phi
    self-term, which is always >= w_i.  Callers drop w=0 rows.
    """
    denom, numer = score_sums(x, w, h_s, tiles=tiles)
    safe = jnp.maximum(denom, 1e-30)[:, None]
    return (numer - x * safe) / (h_s * h_s * safe)


def score_at(x, w, y, h_s, *, tiles: TileConfig | None = None):
    """Score of the weighted KDE of X, evaluated at query rows Y: [m, d].

    s(y) = (Σ_i w_i φ(y, x_i) x_i − y Σ_i w_i φ(y, x_i)) / (h_s² Σ_i w_i φ).

    Unlike the train-train pass there is no guaranteed self-term, so the
    denominator can genuinely underflow for far-out queries; the guarded
    division returns 0-ish scores there (flat log-density tail).
    """
    denom, numer = score_sums_at(x, w, y, h_s, tiles=tiles)
    safe = jnp.maximum(denom, 1e-30)[:, None]
    return (numer - y * safe) / (h_s * h_s * safe)


def debias(x, w, h, h_s=None, *, tiles: TileConfig | None = None):
    """Flash debias pass: X^SD = X + (h^2/2) s(X) (paper's score+shift).

    Padding rows are mapped through unchanged (their score is zeroed by the
    w mask on the shift) so downstream eval kernels see finite inputs.
    """
    if h_s is None:
        h_s = h / math.sqrt(2.0)
    shift = 0.5 * h * h * score(x, w, h_s, tiles=tiles)
    return x + shift * w[:, None]
