"""L1 flash KDE kernel: streaming, tiled, GEMM-formulated Gaussian KDE.

This is the paper's final-stage kernel (§4, "G_KDE = X^SD Y^T"): the
pairwise interaction between queries and (debiased) training points is
computed tile-by-tile as

    ||y_i - x_j||^2 = ||y_i||^2 + ||x_j||^2 - 2 <y_i, x_j>

where the inner-product term is a [BM, d] x [d, BN] matmul that maps onto
the matrix unit (Tensor Cores in the paper, the MXU here).  Each grid step
loads one query block and one train block into VMEM, accumulates the
weighted kernel-sum into the output block, and never materializes the full
[m, n] matrix — the paper's "streaming accumulation".

The kernel returns the *raw* weighted sum  sum_j w_j phi(y_i, x_j); the
Gaussian normalization 1/(count h^d (2pi)^{d/2}) is a per-row scalar applied
by the wrapper so it fuses into the XLA epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    TileConfig,
    normalizer,
    pad_rows,
    padded_sizes,
    pick_tiles,
    validate_pairwise_args,
)


def _kde_kernel(y_ref, x_ref, w_ref, h_ref, o_ref):
    """One [BM, BN] tile: o[i] += sum_j w_j exp(-||y_i - x_j||^2 / 2h^2)."""
    j = pl.program_id(1)

    y = y_ref[...]                                   # [BM, d]   query block
    x = x_ref[...]                                   # [BN, d]   train block
    w = w_ref[...]                                   # [BN]
    h = h_ref[0, 0]

    # GEMM-form squared distances (the Tensor-Core/MXU-mapped part).
    y2 = jnp.sum(y * y, axis=1, keepdims=True)       # [BM, 1]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)       # [BN, 1]
    cross = jax.lax.dot_general(
        y, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # [BM, BN]
    d2 = jnp.maximum(y2 + x2.T - 2.0 * cross, 0.0)

    phi = jnp.exp(-d2 / (2.0 * h * h)) * w[None, :]  # [BM, BN]
    partial = jnp.sum(phi, axis=1)                   # [BM]

    # Streaming accumulation across the reduction grid dimension.
    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


def kde_raw(x, w, y, h, *, tiles: TileConfig | None = None):
    """Unnormalized flash KDE sums, [m].

    Args:
      x: [n, d] train points (rows with w=0 are padding and must be finite).
      w: [n] 0/1 validity weights.
      y: [m, d] query points.
      h: scalar bandwidth (traced — one artifact serves all bandwidths).
      tiles: optional tile override (ablation bench sweeps this).
    """
    validate_pairwise_args(x, w, y)
    m, n = y.shape[0], x.shape[0]
    cfg = pick_tiles(m, n, tiles, d=x.shape[1])
    mp, np_ = padded_sizes(m, n, cfg)

    y_p = pad_rows(y, mp)
    x_p = pad_rows(x, np_)
    w_p = pad_rows(w, np_)                # padded train rows get weight 0
    h_arr = jnp.asarray(h, jnp.float32).reshape(1, 1)

    d = x.shape[1]
    grid = cfg.grid(mp, np_)
    out = pl.pallas_call(
        _kde_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.block_m, d), lambda i, j: (i, 0)),   # Y
            pl.BlockSpec((cfg.block_n, d), lambda i, j: (j, 0)),   # X
            pl.BlockSpec((cfg.block_n,), lambda i, j: (j,)),       # w
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),             # h
        ],
        out_specs=pl.BlockSpec((cfg.block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=True,
    )(y_p, x_p, w_p, h_arr)
    return out[:m]


def kde(x, w, y, h, *, tiles: TileConfig | None = None):
    """Normalized flash KDE density estimate at Y, [m]."""
    validate_pairwise_args(x, w, y)
    d = x.shape[1]
    count = jnp.sum(w)
    return kde_raw(x, w, y, h, tiles=tiles) * normalizer(h, d) / count


# Convenience partial for sweeps: kde with a fixed tile configuration.
def kde_with_tiles(block_m: int, block_n: int):
    """Returns a kde() closure pinned to a (BLOCK_M, BLOCK_N) tiling."""
    cfg = TileConfig(block_m=block_m, block_n=block_n)
    return functools.partial(kde, tiles=cfg)
