"""Pure-jnp oracles for every Flash-SD-KDE kernel.

These are the correctness ground truth: simple, obviously-correct
implementations that materialize the full pairwise interaction matrices.
Every Pallas kernel and every fused pipeline is `assert_allclose`-checked
against these in python/tests/, and the Rust native estimators mirror the
same formulas (DESIGN.md §6).

Conventions (shared across the whole stack):
  X : [n, d]  training points         w : [n] 0/1 validity weights
  Y : [m, d]  query points            h : evaluation bandwidth
  h_s : score bandwidth (default h/sqrt(2), the heat-semigroup t' = t/2)
  count = sum(w) is the effective sample size used for normalization.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from .common import gaussian_log_norm


def sq_dists(a, b):
    """Pairwise squared Euclidean distances, [len(a), len(b)].

    Uses the GEMM form ||a||^2 + ||b||^2 - 2 a.b^T (the paper's eq. in §4),
    clamped at zero against fp cancellation.
    """
    a2 = jnp.sum(a * a, axis=1, keepdims=True)          # [na, 1]
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T        # [1, nb]
    d2 = a2 + b2 - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def gaussian_matrix(a, b, h):
    """phi_ij = exp(-||a_i - b_j||^2 / (2 h^2)), [na, nb]."""
    return jnp.exp(-sq_dists(a, b) / (2.0 * h * h))


def kde_ref(x, w, y, h):
    """Weighted Gaussian KDE of X evaluated at Y. Returns [m].

    p(y) = 1/(count * h^d * (2pi)^{d/2}) * sum_i w_i phi(y, x_i)
    """
    d = x.shape[1]
    count = jnp.sum(w)
    phi = gaussian_matrix(y, x, h)                      # [m, n]
    raw = phi @ w                                       # [m]
    norm = jnp.exp(-gaussian_log_norm(d)) / (h ** d)
    return raw * norm / count


def score_ref(x, w, h_s):
    """Empirical KDE score at each training point. Returns [n, d].

    s(x_i) = (sum_j w_j phi_ij x_j - x_i sum_j w_j phi_ij)
             / (h_s^2 sum_j w_j phi_ij)
    which is the identity-decomposed form of
    sum_j -(x_i - x_j) phi_ij / (h_s^2 sum_j phi_ij)   (paper §1, §4).
    """
    phi = gaussian_matrix(x, x, h_s) * w[None, :]       # [n, n]
    denom = jnp.sum(phi, axis=1, keepdims=True)         # [n, 1]
    numer = phi @ x                                     # [n, d]  (T = Phi X)
    return (numer - x * denom) / (h_s * h_s * denom)


def score_at_ref(x, w, y, h_s):
    """Score of the weighted KDE of X evaluated at query rows Y, [m, d]."""
    phi = gaussian_matrix(y, x, h_s) * w[None, :]       # [m, n]
    denom = jnp.maximum(jnp.sum(phi, axis=1, keepdims=True), 1e-30)
    numer = phi @ x                                     # [m, d]
    return (numer - y * denom) / (h_s * h_s * denom)


def debias_ref(x, w, h, h_s=None):
    """Debiased samples X^SD = X + (h^2/2) * score(X). Returns [n, d]."""
    if h_s is None:
        h_s = h / math.sqrt(2.0)
    return x + 0.5 * h * h * score_ref(x, w, h_s)


def sdkde_ref(x, w, y, h, h_s=None):
    """Full SD-KDE: debias X then evaluate a vanilla KDE at Y. Returns [m]."""
    return kde_ref(debias_ref(x, w, h, h_s), w, y, h)


def laplace_factor(d2, h, d):
    """Laplace correction factor (1 + d/2 - ||u||^2 / (2 h^2))."""
    return 1.0 + 0.5 * d - d2 / (2.0 * h * h)


def laplace_ref(x, w, y, h):
    """Laplace-corrected KDE (paper §5). Returns [m]; may be negative.

    p_LC(y) = 1/(count h^d (2pi)^{d/2})
              * sum_i w_i phi(y, x_i) (1 + d/2 - ||y - x_i||^2/(2h^2))
    """
    d = x.shape[1]
    count = jnp.sum(w)
    d2 = sq_dists(y, x)                                 # [m, n]
    phi = jnp.exp(-d2 / (2.0 * h * h))
    corrected = phi * laplace_factor(d2, h, d)
    raw = corrected @ w
    norm = jnp.exp(-gaussian_log_norm(d)) / (h ** d)
    return raw * norm / count


def negative_mass_ref(pdf_values, true_pdf_values):
    """Importance-sampled integrated negative mass: E_p[max(0,-p_hat)/p].

    Diagnostic for the signed Laplace estimator (paper §6.1): samples are
    drawn from the true density p, so 1/p weights turn the mean into the
    integral of the negative part.
    """
    neg = jnp.maximum(0.0, -pdf_values)
    return jnp.mean(neg / true_pdf_values)
