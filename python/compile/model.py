"""L2 JAX pipelines: the compute graphs the Rust coordinator executes.

Each pipeline is a pure jax function over traced arrays; ``aot.py`` lowers
one HLO artifact per (pipeline, variant, d, shape-bucket).  Bandwidths and
weights are runtime inputs, so a single artifact serves any bandwidth and
any actual sample count <= the bucket (padding rows carry w=0).

Variants (DESIGN.md §3 maps these to the paper's baselines):

  flash   — L1 Pallas streaming kernels (the paper's contribution).
  gemm    — pure-jnp GEMM formulation that *materializes* the full Gram
            matrix (the "SD-KDE (Torch)" strong baseline).
  stream  — lax.map over query/train row blocks, no materialization but no
            explicit tile/matrix-unit mapping (the PyKeOps analogue).
  naive   — broadcasted [m, n, d] difference tensor, no GEMM decomposition
            (the scalar-formulation "scikit-learn" analogue; small shapes
            only — its memory footprint is the point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import TileConfig, kde as kde_flash
from .kernels import laplace_fused as laplace_flash_fused
from .kernels import laplace_nonfused as laplace_flash_nonfused
from .kernels import debias as debias_flash
from .kernels import score_at as score_at_flash
from .kernels import ref
from .kernels.common import gaussian_log_norm

VARIANTS = ("flash", "gemm", "stream", "naive")

# Row-block width for the stream (KeOps-like) variants.
STREAM_BLOCK = 128


def _norm(h, d, count):
    return jnp.exp(-gaussian_log_norm(d)) / (h ** d) / count


# ---------------------------------------------------------------------------
# KDE evaluation pipelines: (x, w, y, h) -> pdf [m]
# ---------------------------------------------------------------------------

def kde_gemm(x, w, y, h):
    """Materializing GEMM-form KDE (the Torch-style strong baseline)."""
    return ref.kde_ref(x, w, y, h)


def kde_naive(x, w, y, h):
    """Broadcasted elementwise KDE: materializes [m, n, d] differences."""
    d = x.shape[1]
    diff = y[:, None, :] - x[None, :, :]              # [m, n, d]
    d2 = jnp.sum(diff * diff, axis=2)                 # [m, n]
    phi = jnp.exp(-d2 / (2.0 * h * h))
    return (phi @ w) * _norm(h, d, jnp.sum(w))


def kde_stream(x, w, y, h):
    """Streaming row-block KDE without tile/MXU mapping (KeOps analogue).

    lax.map walks query blocks; each step reduces against the full train
    set with jnp ops.  Memory stays O(block * n) like a LazyTensor
    reduction, but XLA sees narrow GEMMs instead of the tiled formulation.
    """
    m, d = y.shape
    block = min(STREAM_BLOCK, m)
    if m % block != 0:
        raise ValueError(f"stream variant needs block | m (m={m}, block={block})")
    yb = y.reshape(m // block, block, d)

    def step(yblk):
        d2 = ref.sq_dists(yblk, x)
        phi = jnp.exp(-d2 / (2.0 * h * h))
        return phi @ w

    raw = jax.lax.map(step, yb).reshape(m)
    return raw * _norm(h, d, jnp.sum(w))


def kde_pipeline(variant: str):
    """KDE eval pipeline for a variant: (x, w, y, h) -> pdf."""
    return {
        "flash": lambda x, w, y, h: kde_flash(x, w, y, h),
        "gemm": kde_gemm,
        "stream": kde_stream,
        "naive": kde_naive,
    }[variant]


# ---------------------------------------------------------------------------
# SD-KDE fit pipelines: (x, w, h, h_s) -> x_sd [n, d]
# ---------------------------------------------------------------------------

def sdkde_fit_gemm(x, w, h, h_s):
    """Materializing score + shift (Torch-style)."""
    return x + (0.5 * h * h * ref.score_ref(x, w, h_s)) * w[:, None]


def sdkde_fit_stream(x, w, h, h_s):
    """Streaming score: lax.map over train row blocks (KeOps analogue)."""
    n, d = x.shape
    block = min(STREAM_BLOCK, n)
    if n % block != 0:
        raise ValueError(f"stream variant needs block | n (n={n}, block={block})")
    xb = x.reshape(n // block, block, d)

    def step(xblk):
        phi = jnp.exp(-ref.sq_dists(xblk, x) / (2.0 * h_s * h_s)) * w[None, :]
        denom = jnp.sum(phi, axis=1, keepdims=True)
        numer = phi @ x
        return (numer - xblk * denom) / (h_s * h_s * denom)

    s = jax.lax.map(step, xb).reshape(n, d)
    return x + (0.5 * h * h * s) * w[:, None]


def sdkde_fit_pipeline(variant: str):
    """Fit (score + shift) pipeline: (x, w, h, h_s) -> x_sd."""
    return {
        "flash": lambda x, w, h, h_s: debias_flash(x, w, h, h_s),
        "gemm": sdkde_fit_gemm,
        "stream": sdkde_fit_stream,
    }[variant]


# ---------------------------------------------------------------------------
# End-to-end SD-KDE: (x, w, y, h, h_s) -> pdf [m]
# ---------------------------------------------------------------------------

def sdkde_e2e_pipeline(variant: str):
    """Full SD-KDE (fit then eval) in one artifact, for single-shot benches."""
    fit = sdkde_fit_pipeline(variant)
    ev = kde_pipeline(variant)

    def run(x, w, y, h, h_s):
        return ev(fit(x, w, h, h_s), w, y, h)

    return run


# ---------------------------------------------------------------------------
# Laplace-corrected KDE: (x, w, y, h) -> pdf [m] (signed)
# ---------------------------------------------------------------------------

def laplace_gemm(x, w, y, h):
    return ref.laplace_ref(x, w, y, h)


def laplace_pipeline(variant: str):
    """Laplace-corrected pipelines; 'flash' vs 'nonfused' measures Fig. 4."""
    return {
        "flash": lambda x, w, y, h: laplace_flash_fused(x, w, y, h),
        "nonfused": lambda x, w, y, h: laplace_flash_nonfused(x, w, y, h),
        "gemm": laplace_gemm,
    }[variant]


# ---------------------------------------------------------------------------
# Score (gradient) serving: (x, w, y, h_score) -> s [m, d]
#
# The gradient of the fitted log-density at arbitrary query points —
# the extension feature behind the Langevin-sampling example.  The flash
# variant reuses the paper's streaming score kernel with query rows as the
# output blocks; gemm materializes [m, n] (baseline).
# ---------------------------------------------------------------------------

def score_eval_gemm(x, w, y, h_s):
    return ref.score_at_ref(x, w, y, h_s)


def score_eval_pipeline(variant: str):
    """Gradient-serving pipeline: (x, w, y, h_score) -> grad [m, d]."""
    return {
        "flash": lambda x, w, y, h_s: score_at_flash(x, w, y, h_s),
        "gemm": score_eval_gemm,
    }[variant]


# ---------------------------------------------------------------------------
# Pipeline registry used by aot.py and the tests.
# ---------------------------------------------------------------------------

def pipeline_signature(pipeline: str, n: int, m: int, d: int):
    """(input specs, variant->callable) for a pipeline family at a bucket.

    Input specs are (name, shape) pairs; all dtypes are f32.  The order here
    is the wire order the Rust engine uses — keep in sync with
    rust/src/runtime/engine.rs.
    """
    if pipeline == "kde":
        return (
            [("x", (n, d)), ("w", (n,)), ("y", (m, d)), ("h", ())],
            kde_pipeline,
        )
    if pipeline == "sdkde_fit":
        return (
            [("x", (n, d)), ("w", (n,)), ("h", ()), ("h_score", ())],
            sdkde_fit_pipeline,
        )
    if pipeline == "sdkde_e2e":
        return (
            [("x", (n, d)), ("w", (n,)), ("y", (m, d)), ("h", ()), ("h_score", ())],
            sdkde_e2e_pipeline,
        )
    if pipeline == "laplace":
        return (
            [("x", (n, d)), ("w", (n,)), ("y", (m, d)), ("h", ())],
            laplace_pipeline,
        )
    if pipeline == "score_eval":
        return (
            [("x", (n, d)), ("w", (n,)), ("y", (m, d)), ("h_score", ())],
            score_eval_pipeline,
        )
    raise ValueError(f"unknown pipeline {pipeline!r}")


def build_fn(pipeline: str, variant: str, n: int, m: int, d: int,
             tiles: TileConfig | None = None):
    """Concrete callable + input names + ShapeDtypeStructs for lowering."""
    specs, factory = pipeline_signature(pipeline, n, m, d)
    fn = factory(variant)
    if tiles is not None:
        # Tile-pinned flash pipelines for the §6.2 block-sweep ablation.
        if pipeline == "sdkde_fit" and variant == "flash":
            fn = lambda x, w, h, h_s: debias_flash(x, w, h, h_s, tiles=tiles)
        elif pipeline == "kde" and variant == "flash":
            fn = lambda x, w, y, h: kde_flash(x, w, y, h, tiles=tiles)
        else:
            raise ValueError("tile override only supported for flash kde/fit")
    shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    names = [nm for nm, _ in specs]
    return fn, names, shapes
