"""AOT driver: lower every (pipeline, variant, d, bucket) to HLO text.

This is the only place python touches the artifact directory.  The output
format is HLO **text** (not ``lowered.compile().serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are indexed by ``manifest.json``; the Rust artifact store
(rust/src/runtime/artifact.rs) consumes exactly this schema:

    {"version": 1,
     "entries": [{"pipeline": "kde", "variant": "flash", "d": 16,
                  "n": 512, "m": 64, "tiles": null,
                  "file": "kde__flash__d16__n512__m64.hlo.txt",
                  "inputs": [{"name": "x", "shape": [512, 16]}, ...],
                  "outputs": [{"shape": [64]}]}]}

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick] [--no-sweep]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .kernels import TileConfig
from .model import build_fn

# ---------------------------------------------------------------------------
# Bucket plan (DESIGN.md §9).
# ---------------------------------------------------------------------------

# Bench buckets: m = n/8 as in the paper's experiments.
BENCH_N_16D = (512, 1024, 2048, 4096, 8192)
BENCH_N_1D = (1024, 4096, 16384)

# naive materializes [m, n, d]; cap its buckets so the artifact stays sane.
NAIVE_MAX_N = 1024

# Serving buckets: query batches the dynamic batcher targets.
SERVING_M = (64, 256)

# §6.2 launch-parameter sweep (BLOCK_M x BLOCK_N), at a fixed fit problem.
# The second row mirrors the paper's finding that large tiles win (§6.2
# landed on 64x1024 on the A6000); the perf pass (EXPERIMENTS.md §Perf)
# re-tuned the defaults from this sweep.
SWEEP_TILES = (
    (32, 64), (32, 256), (64, 128), (64, 256), (64, 512), (128, 256),
    (128, 512), (128, 1024), (256, 512), (256, 1024),
)
SWEEP_N, SWEEP_D = 2048, 16

QUICK_N_16D = (512,)
QUICK_N_1D = (1024,)


def plan_entries(quick: bool = False, sweep: bool = True) -> list[dict]:
    """The full artifact plan as manifest-shaped dicts (file/io unset)."""
    entries: list[dict] = []
    seen: set[str] = set()

    def add(pipeline, variant, d, n, m, tiles=None):
        e = {
            "pipeline": pipeline,
            "variant": variant,
            "d": d,
            "n": n,
            "m": m,
            "tiles": list(tiles) if tiles else None,
        }
        # Bench and serving buckets can coincide (e.g. n=512 -> m=64 twice).
        name = entry_filename(e)
        if name not in seen:
            seen.add(name)
            entries.append(e)

    for d, sizes in ((16, QUICK_N_16D if quick else BENCH_N_16D),
                     (1, QUICK_N_1D if quick else BENCH_N_1D)):
        for n in sizes:
            m = n // 8
            for variant in ("flash", "gemm", "stream"):
                add("kde", variant, d, n, m)
                add("sdkde_e2e", variant, d, n, m)
                add("sdkde_fit", variant, d, n, m)
            if n <= NAIVE_MAX_N:
                add("kde", "naive", d, n, m)
            for variant in ("flash", "nonfused", "gemm"):
                add("laplace", variant, d, n, m)
            # Serving eval buckets: flash KDE at small query batches.
            for sm in SERVING_M:
                add("kde", "flash", d, n, sm)
            # Gradient serving (∇log p̂ at queries): flash + gemm baseline.
            add("score_eval", "flash", d, n, m)
            for sm in SERVING_M:
                add("score_eval", "flash", d, n, sm)
            add("score_eval", "gemm", d, n, m)

    if sweep and not quick:
        for bm, bn in SWEEP_TILES:
            add("sdkde_fit", "flash", SWEEP_D, SWEEP_N, SWEEP_N // 8,
                tiles=(bm, bn))
    return entries


def entry_filename(e: dict) -> str:
    base = f"{e['pipeline']}__{e['variant']}__d{e['d']}__n{e['n']}__m{e['m']}"
    if e.get("tiles"):
        base += f"__bm{e['tiles'][0]}__bn{e['tiles'][1]}"
    return base + ".hlo.txt"


# ---------------------------------------------------------------------------
# Lowering.
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(e: dict) -> tuple[str, list[dict], list[dict]]:
    """Lower one plan entry; returns (hlo_text, input specs, output specs)."""
    tiles = TileConfig(*e["tiles"]) if e.get("tiles") else None
    fn, names, shapes = build_fn(
        e["pipeline"], e["variant"], e["n"], e["m"], e["d"], tiles=tiles
    )
    lowered = jax.jit(fn).lower(*shapes)
    text = to_hlo_text(lowered)
    inputs = [
        {"name": nm, "shape": list(s.shape)} for nm, s in zip(names, shapes)
    ]
    out_aval = jax.eval_shape(fn, *shapes)
    out_list = out_aval if isinstance(out_aval, (tuple, list)) else [out_aval]
    outputs = [{"shape": list(o.shape)} for o in out_list]
    return text, inputs, outputs


def plan_digest(entries: list[dict]) -> str:
    """Stable digest of the plan + kernel sources, for make-style freshness."""
    h = hashlib.sha256()
    h.update(json.dumps(entries, sort_keys=True).encode())
    pkg = os.path.dirname(__file__)
    for root, _, files in os.walk(pkg):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def build_artifacts(out_dir: str, quick: bool, sweep: bool,
                    verbose: bool = True) -> dict:
    entries = plan_entries(quick=quick, sweep=sweep)
    digest = plan_digest(entries)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    # Freshness check: skip the (multi-minute) lowering loop when nothing
    # in the plan or the kernel sources changed.
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("digest") == digest and all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for e in old.get("entries", [])
            ):
                if verbose:
                    print(f"artifacts up to date ({len(old['entries'])} entries)")
                return old
        except (json.JSONDecodeError, KeyError):
            pass

    manifest = {"version": 1, "digest": digest, "entries": []}
    t0 = time.time()
    for i, e in enumerate(entries):
        fname = entry_filename(e)
        t1 = time.time()
        text, inputs, outputs = lower_entry(e)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rec = dict(e, file=fname, inputs=inputs, outputs=outputs)
        manifest["entries"].append(rec)
        if verbose:
            print(
                f"[{i + 1}/{len(entries)}] {fname} "
                f"({len(text) / 1024:.0f} KiB, {time.time() - t1:.2f}s)"
            )
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(entries)} artifacts in {time.time() - t0:.1f}s")
    return manifest


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--quick", action="store_true",
                   help="reduced bucket set for CI-style runs")
    p.add_argument("--no-sweep", action="store_true",
                   help="skip the §6.2 block-size sweep artifacts")
    args = p.parse_args(argv)
    build_artifacts(args.out_dir, quick=args.quick, sweep=not args.no_sweep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
