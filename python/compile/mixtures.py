"""Gaussian-mixture benchmark densities (build-time twin of rust data::mixture).

The paper evaluates on "a simple 16-D Gaussian mixture" (§6) and a 1-D
mixture-of-Gaussians oracle benchmark (Fig. 3).  We fix two canonical
mixtures, shared *by parameter value* with the Rust data layer so oracle
densities agree across the stack:

  * ``mix1d``  — trimodal 1-D mixture (well-separated + one broad mode).
  * ``mix16d`` — 4-component 16-D mixture with isotropic components placed
    on a simplex-like frame, spread wide enough that debiasing matters.

Components are isotropic (covariance sigma^2 I) so the true pdf is cheap to
evaluate in any dimension.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Mixture:
    """Isotropic Gaussian mixture: weights[k], means[k, d], sigmas[k]."""

    weights: tuple
    means: tuple          # tuple of tuples, k x d
    sigmas: tuple

    @property
    def d(self) -> int:
        return len(self.means[0])

    @property
    def k(self) -> int:
        return len(self.weights)

    def sample(self, n: int, seed: int) -> np.ndarray:
        """Draw n samples, [n, d] float32, deterministic in seed."""
        rng = np.random.default_rng(seed)
        comp = rng.choice(self.k, size=n, p=np.asarray(self.weights))
        means = np.asarray(self.means)[comp]                     # [n, d]
        sig = np.asarray(self.sigmas)[comp][:, None]             # [n, 1]
        return (means + sig * rng.standard_normal((n, self.d))).astype(
            np.float32
        )

    def pdf(self, x: np.ndarray) -> np.ndarray:
        """True density at x ([m, d]), float64 for metric stability."""
        x = np.asarray(x, np.float64)
        out = np.zeros(x.shape[0])
        for wk, mu, sig in zip(self.weights, self.means, self.sigmas):
            diff = x - np.asarray(mu)
            d2 = np.sum(diff * diff, axis=1)
            norm = (2.0 * math.pi) ** (self.d / 2.0) * sig ** self.d
            out += wk * np.exp(-d2 / (2.0 * sig * sig)) / norm
        return out


def mix1d() -> Mixture:
    """Trimodal 1-D benchmark mixture (two sharp modes + one broad)."""
    return Mixture(
        weights=(0.45, 0.35, 0.20),
        means=((-2.0,), (1.5,), (5.0,)),
        sigmas=(0.6, 0.4, 1.2),
    )


def _frame_means(d: int, k: int, radius: float) -> tuple:
    """k well-separated means on +/- coordinate axes of R^d."""
    means = []
    for i in range(k):
        mu = [0.0] * d
        mu[i % d] = radius if (i // d) % 2 == 0 else -radius
        means.append(tuple(mu))
    return tuple(means)


def mix16d() -> Mixture:
    """4-component 16-D benchmark mixture (paper's high-d setting)."""
    return Mixture(
        weights=(0.4, 0.3, 0.2, 0.1),
        means=_frame_means(16, 4, 3.0),
        sigmas=(1.0, 0.8, 1.2, 0.9),
    )


def by_dim(d: int) -> Mixture:
    """Canonical benchmark mixture for dimension d."""
    if d == 1:
        return mix1d()
    if d == 16:
        return mix16d()
    # Generic fallback used by shape-sweep tests: 2 components.
    return Mixture(
        weights=(0.6, 0.4),
        means=(tuple([1.5] * d), tuple([-1.5] * d)),
        sigmas=(1.0, 0.7),
    )
