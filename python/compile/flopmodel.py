"""Paper §4.1 + Appendix A FLOP / bytes / arithmetic-intensity models.

Mirrored in Rust (``analysis::flops``) — python/tests cross-check the
specific constants quoted in the paper (81.5 k^2 FLOPs and 1.13 k^2 bytes
for d=16; 17.75 k^2 FLOPs for d=1) so both implementations stay pinned to
the published model.

Conventions follow the paper exactly:
  * one exp costs 8 FLOP-equivalents (A6000 SFU:FP32 ratio 128:16),
  * n_test = n_train / 8 unless stated,
  * tile-byte model uses the paper's best launch (BLOCK_M=64, BLOCK_N=1024).
"""

from __future__ import annotations

import dataclasses

EXP_FLOPS = 8.0  # SFU-costed exponential, paper §3

# Paper's best launch parameters for the byte model (§4.1).
PAPER_BLOCK_M = 64
PAPER_BLOCK_N = 1024


@dataclasses.dataclass(frozen=True)
class FlopEstimate:
    flops: float
    bytes: float

    @property
    def intensity(self) -> float:
        return self.flops / self.bytes


def sdkde_flops_d(k: float, d: int, n_test: float | None = None) -> float:
    """Total FLOPs for the d-dimensional SD-KDE pipeline (§4.1).

    Stage 1: score Gram  G = X X^T            -> 2 d k^2
    Stage 2: numerator   T = Phi X            -> 2 d k^2 + 4 k^2 + 8 k^2
    Stage 3: final KDE on debiased data       -> 2 d k m + 4 k m + 8 k m
    """
    m = k / 8.0 if n_test is None else float(n_test)
    gram = 2.0 * d * k * k
    numer = 2.0 * d * k * k + 4.0 * k * k + EXP_FLOPS * k * k
    final = 2.0 * d * k * m + 4.0 * k * m + EXP_FLOPS * k * m
    return gram + numer + final


def sdkde_bytes_d(
    k: float,
    d: int,
    block_m: int = PAPER_BLOCK_M,
    block_n: int = PAPER_BLOCK_N,
) -> float:
    """GDDR traffic of the tiled score pass, paper's tile-byte model (§4.1).

    Per tile: load the [BM, d] output-row block once, stream the [BN, d]
    train block, write the [BM]-pdf partial and the [BM, d] weighted sums:
      4 (BM d + BN d + BM + BM d) bytes,
    times (k / BM)(k / BN) tiles.
    """
    per_tile = 4.0 * (2.0 * block_m * d + block_n * d + block_m)
    tiles = (k / block_m) * (k / block_n)
    return per_tile * tiles


def sdkde_estimate_d(k: float, d: int) -> FlopEstimate:
    """Combined §4.1 estimate; for d=16 reproduces ~81.5 k^2 / ~1.13 k^2."""
    return FlopEstimate(flops=sdkde_flops_d(k, d), bytes=sdkde_bytes_d(k, d))


def machine_balance_flops_per_byte(
    peak_tflops: float = 155.0, bandwidth_gbs: float = 770.0
) -> float:
    """A6000 Tensor-Core machine balance (~200 flops/byte, §4.1)."""
    return peak_tflops * 1e12 / (bandwidth_gbs * 1e9)


# ---------------------------------------------------------------------------
# Appendix A: the 1-D model.
# ---------------------------------------------------------------------------

C1_SCORE_PAIR = 16.0  # one exp (8) + ~eight scalar ops per (train, train) pair
C2_KDE_PAIR = 14.0    # one exp (8) + ~six scalar ops per (train, test) pair


def sdkde_flops_1d(k: float, n_test: float | None = None) -> float:
    """Appendix A total: ~16 k^2 + 14 k m  (=17.75 k^2 at m = k/8)."""
    m = k / 8.0 if n_test is None else float(n_test)
    return C1_SCORE_PAIR * k * k + C2_KDE_PAIR * k * m


def sdkde_bytes_1d(k: float, n_test: float | None = None) -> float:
    """Appendix A traffic: one read of train/test, one write of outputs.

    At m=k/8 and 4-byte floats this is ~5k bytes: 4k (train) + 0.5k (test)
    + 0.5k (out).
    """
    m = k / 8.0 if n_test is None else float(n_test)
    return 4.0 * (k + m) + 4.0 * m


def sdkde_estimate_1d(k: float) -> FlopEstimate:
    return FlopEstimate(flops=sdkde_flops_1d(k), bytes=sdkde_bytes_1d(k))


def utilization(flops: float, runtime_s: float, peak_flops: float) -> float:
    """Fraction of peak sustained given the model FLOPs and a measured time."""
    if runtime_s <= 0.0 or peak_flops <= 0.0:
        raise ValueError("runtime and peak must be positive")
    return flops / runtime_s / peak_flops
