//! Domain example: 1-D density landscape — KDE vs SD-KDE vs truth.
//!
//! Renders the trimodal benchmark mixture as an ASCII landscape and shows
//! how the score-debiased estimator sharpens the modes that vanilla KDE
//! (Silverman bandwidth) oversmooths — the statistical story behind the
//! paper's Figs. 2/3, visible with the naked eye.
//!
//! ```bash
//! make artifacts && cargo run --release --example density_landscape
//! ```

use flash_sdkde::config::Config;
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::mix1d;
use flash_sdkde::estimator::EstimatorKind;
use flash_sdkde::util::rng::Pcg64;

const COLS: usize = 72;
const LO: f32 = -5.0;
const HI: f32 = 9.0;

fn sparkline(values: &[f64], peak: f64) -> String {
    const LEVELS: &[char] = &[' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    values
        .iter()
        .map(|&v| {
            let t = (v / peak).clamp(0.0, 1.0);
            LEVELS[(t * (LEVELS.len() - 1) as f64).round() as usize]
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into();
    // No artifacts? Serve the pure-Rust native flash backend instead.
    let cfg = cfg.auto_backend();
    let coordinator = Coordinator::start(cfg)?;

    let mix = mix1d();
    let mut rng = Pcg64::seeded(5);
    let n = 900;
    let train = mix.sample(n, &mut rng);

    // Fit both estimators on identical data with the *same* bandwidth
    // (Silverman), so the visible difference is purely the score debiasing.
    // (The SD-rate rule h ~ n^{-1/(d+8)} pays off asymptotically, but at
    // n=900 on a sharply trimodal density the leading-order correction
    // can't recover from that much smoothing — see EXPERIMENTS.md.)
    let kde_model =
        coordinator.fit("kde", train.clone(), &FitSpec::new(EstimatorKind::Kde, 1))?;
    let sd_model = coordinator.fit(
        "sdkde",
        train,
        &FitSpec::new(EstimatorKind::SdKde, 1).bandwidth(kde_model.h()),
    )?;

    // Evaluate on a grid.
    let grid: Vec<f32> = (0..COLS)
        .map(|i| LO + (HI - LO) * i as f32 / (COLS - 1) as f32)
        .collect();
    let kde = coordinator.eval(&kde_model, grid.clone())?;
    let sdkde = coordinator.eval(&sd_model, grid.clone())?;
    let truth: Vec<f64> = grid.iter().map(|&x| mix.pdf1(&[x])).collect();

    let kde_v: Vec<f64> = kde.values.iter().map(|&v| v as f64).collect();
    let sd_v: Vec<f64> = sdkde.values.iter().map(|&v| v as f64).collect();
    let peak = truth
        .iter()
        .chain(&kde_v)
        .chain(&sd_v)
        .fold(0.0f64, |a, &b| a.max(b));

    println!("x in [{LO}, {HI}], n_train = {n}\n");
    println!("truth  |{}|", sparkline(&truth, peak));
    println!("kde    |{}|", sparkline(&kde_v, peak));
    println!("sd-kde |{}|", sparkline(&sd_v, peak));

    // Quantify: SD-KDE must be closer to the truth in MSE on the grid.
    let mse = |est: &[f64]| -> f64 {
        est.iter()
            .zip(&truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / truth.len() as f64
    };
    let mse_kde = mse(&kde_v);
    let mse_sd = mse(&sd_v);
    println!("\ngrid MSE: kde={mse_kde:.3e}  sd-kde={mse_sd:.3e}  (improvement {:.2}x)",
        mse_kde / mse_sd);
    anyhow::ensure!(mse_sd < mse_kde, "SD-KDE should beat KDE here");
    println!("density_landscape OK");
    Ok(())
}
