//! Extension example: Langevin sampling from a *fitted* density.
//!
//! The score is the paper's central object; this example shows the served
//! gradient mode (`QuerySpec::grad`, the streaming score kernel at
//! arbitrary query points) powering unadjusted Langevin dynamics
//!
//!     y_{t+1} = y_t + (ε/2) ∇log p̂(y_t) + √ε ξ_t,   ξ_t ~ N(0, I)
//!
//! over a KDE fitted to the 1-D trimodal benchmark mixture.  Gradients
//! flow through the same bounded queue and dynamic batcher as densities,
//! so each request reports its co-batch size and shows up in the server
//! metrics.  After burn-in the chain's histogram must match the *fitted
//! density itself* (served by the density mode) — the two modes
//! cross-validate: grad-driven samples must reproduce eval densities, and
//! score errors would compound over hundreds of steps.
//!
//! ```bash
//! make artifacts && cargo run --release --example langevin_sampler
//! ```

use flash_sdkde::config::Config;
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::mix1d;
use flash_sdkde::estimator::EstimatorKind;
use flash_sdkde::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into();
    // No artifacts? Serve the pure-Rust native flash backend instead.
    let cfg = cfg.auto_backend();
    let coordinator = Coordinator::start(cfg)?;

    // Fit a KDE on the trimodal mixture.
    let mix = mix1d();
    let mut rng = Pcg64::seeded(17);
    let n = 1000;
    let train = mix.sample(n, &mut rng);
    let target = coordinator.fit("target", train, &FitSpec::new(EstimatorKind::Kde, 1))?;
    println!("fitted target density: n={} h={:.4}", target.n(), target.h());

    // Langevin dynamics: a population of chains stepped in lock-step so
    // each iteration is ONE batched grad request (the serving win).
    let chains = 256;
    let steps = 400;
    let burn_in = 100;
    let eps = 0.02f32; // small step: ULA bias is O(eps)
    // Warm start: init chains at fresh draws from the data distribution
    // (close to stationarity; burn-in only has to erase the ULA bias).
    let mut y: Vec<f32> = mix.sample(chains, &mut rng);
    let mut samples: Vec<f32> = Vec::new();
    for t in 0..steps {
        let grads = coordinator.grad(&target, y.clone())?.values;
        for (yi, g) in y.iter_mut().zip(&grads) {
            *yi += 0.5 * eps * g + (eps.sqrt()) * rng.normal() as f32;
        }
        if t >= burn_in {
            samples.extend_from_slice(&y);
        }
    }
    println!(
        "collected {} samples from {chains} chains \
         ({} grad requests through the batcher, mean batch {:.2})",
        samples.len(),
        steps,
        coordinator.metrics().mean_batch_size()
    );

    // Compare the chain histogram against the *fitted* density served by
    // the eval endpoint (the chain's actual stationary target, up to the
    // O(eps) ULA discretization bias).
    let lo = -6.0f32;
    let hi = 10.0f32;
    let bins = 32;
    let width = (hi - lo) / bins as f32;
    let mut hist = vec![0f64; bins];
    let mut kept = 0usize;
    for &s in &samples {
        if s >= lo && s < hi {
            hist[((s - lo) / width) as usize] += 1.0;
            kept += 1;
        }
    }
    let centers: Vec<f32> =
        (0..bins).map(|b| lo + (b as f32 + 0.5) * width).collect();
    let fitted = coordinator.eval(&target, centers.clone())?.values;

    println!("\n  bin center   chain density   fitted p̂   true mixture");
    let mut tv = 0.0f64; // total-variation distance on the grid
    for b in 0..bins {
        let est = hist[b] / kept as f64 / width as f64;
        let p_hat = fitted[b] as f64;
        tv += 0.5 * (est - p_hat).abs() * width as f64;
        if b % 2 == 0 {
            println!(
                "  {:>9.2}   {est:>13.4}   {p_hat:>9.4}   {:>12.4}",
                centers[b],
                mix.pdf1(&[centers[b]])
            );
        }
    }
    println!("\nTV distance (chain vs fitted p̂): {tv:.4}");
    anyhow::ensure!(tv < 0.1, "Langevin chain diverged from its target p̂");
    anyhow::ensure!(kept as f64 / samples.len() as f64 > 0.98, "mass escaped");
    println!("langevin_sampler OK");
    Ok(())
}
