//! Kernel linear algebra over a fitted model: MatVec, kernel PCA, MMD
//! (DESIGN.md §17).
//!
//! ```bash
//! cargo run --release --no-default-features --example kernel_pca
//! ```
//!
//! Fits a plain KDE model on a 3-d mixture and then drives the linalg
//! pipeline family through the serving path: a raw `K·v` MatVec query
//! (checked against the density identity `p̂ = normalizer/n · K·1`), the
//! top kernel-PCA eigenpair by power iteration (cross-checked against
//! the in-process `linalg::kernel_pca` on the same data), and the MMD
//! two-sample statistic against a fresh draw and against a shifted one.

use flash_sdkde::config::Config;
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::estimator::{flash::TileConfig, EstimatorKind};
use flash_sdkde::linalg::{self, PcaOpts};
use flash_sdkde::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default().auto_backend();
    println!("booting coordinator (backend: {})...", cfg.backend);
    let coordinator = Coordinator::start(cfg)?;

    // 1. Fit a plain KDE model (no score shift, so the resident train set
    //    is exactly the sampled one — the in-process cross-checks below
    //    see the same data the server serves).
    let d = 3;
    let mix = by_dim(d);
    let mut rng = Pcg64::seeded(17);
    let n = 400;
    let train = mix.sample(n, &mut rng);
    let handle =
        coordinator.fit("kpca", train.clone(), &FitSpec::new(EstimatorKind::Kde, d))?;
    println!(
        "fitted model {:?}: n={} bucket={} h={:.4}",
        handle.name(),
        handle.n(),
        handle.bucket_n(),
        handle.h()
    );

    // 2. MatVec: K·1 at the training rows relates to the served density
    //    by p̂(y) = normalizer(h, d)/n · (K·1)(y) — check the identity.
    let ones = vec![1.0f32; n];
    let kv = coordinator.matvec(&handle, train.clone(), ones)?;
    let dens = coordinator.eval(&handle, train.clone())?;
    let h = handle.h();
    let normalizer = (std::f64::consts::TAU).powf(-(d as f64) / 2.0) * h.powi(-(d as i32));
    let max_rel = kv
        .values
        .iter()
        .zip(&dens.values)
        .map(|(&s, &p)| {
            let implied = normalizer / n as f64 * s as f64;
            ((implied - p as f64) / (p as f64).abs().max(1e-30)).abs()
        })
        .fold(0.0f64, f64::max);
    println!("matvec identity p̂ = norm/n · K·1: max rel dev {max_rel:.2e}");
    anyhow::ensure!(max_rel < 1e-3, "matvec diverges from the density identity");

    // 3. Kernel PCA through the serving path (every sweep is a MatVec
    //    query), cross-checked against the in-process implementation.
    let opts = PcaOpts::default();
    let served = coordinator.kernel_pca(&handle, &opts)?;
    let local = linalg::kernel_pca(
        &train,
        &vec![1.0f32; n],
        d,
        h,
        &TileConfig::default(),
        &opts,
    )?;
    println!(
        "kernel PCA: served λ={:.6} ({} sweeps, converged {}) vs local λ={:.6}",
        served.eigenvalue, served.iters, served.converged, local.eigenvalue
    );
    let rel = (served.eigenvalue - local.eigenvalue).abs()
        / local.eigenvalue.abs().max(1.0);
    anyhow::ensure!(rel < 1e-3, "served eigenvalue diverges from local");

    // 4. MMD: a fresh draw from the same mixture scores near zero, a
    //    shifted copy scores high.
    let fresh = mix.sample(n, &mut rng);
    let shifted: Vec<f32> = fresh.iter().map(|&v| v + 4.0).collect();
    let near = coordinator.mmd(&handle, fresh)?;
    let far = coordinator.mmd(&handle, shifted)?;
    println!("mmd vs fresh draw: {:.4e}; vs shifted draw: {:.4e}", near.mmd, far.mmd);
    anyhow::ensure!(far.mmd2 > 10.0 * near.mmd2, "mmd failed to separate");

    // 5. The engine counted every MatVec execution and PCA sweep.
    let stats = coordinator.stats_json();
    let engine = stats.get("engine").expect("engine stats");
    println!(
        "engine: matvec_queries={} power_iters={}",
        engine.get("matvec_queries").and_then(|v| v.as_f64()).unwrap_or(0.0),
        engine.get("power_iters").and_then(|v| v.as_f64()).unwrap_or(0.0),
    );
    println!("kernel_pca example OK");
    Ok(())
}
