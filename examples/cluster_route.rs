//! Multi-node serving demo (DESIGN.md §12): three in-process `serve`
//! workers behind the consistent-hash router, all on loopback ephemeral
//! ports — no artifacts, no XLA, no setup:
//!
//! ```bash
//! cargo run --release --example cluster_route --no-default-features
//! ```
//!
//! Walks the whole lifecycle: fit a handful of models through the router
//! (placement is rendezvous hashing of the model name), query them, dump
//! the aggregated fleet stats, "unplug" one worker to show the typed
//! failure, then update the node table and re-fit to show failover.
//!
//! Pass `--tuning <table.json>` (a `flash-sdkde tune` output) to boot
//! every worker with the tile-tuning table, i.e. a tuned cluster.

use std::path::PathBuf;

use anyhow::Result;

use flash_sdkde::config::{Config, RouterConfig};
use flash_sdkde::coordinator::router::{Router, RouterServer};
use flash_sdkde::coordinator::server::{Client, Server};
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::estimator::EstimatorKind;
use flash_sdkde::runtime::BackendKind;
use flash_sdkde::util::json;
use flash_sdkde::util::rng::Pcg64;

fn worker(tuning: Option<&PathBuf>) -> Result<Server> {
    let mut cfg = Config::default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.batch_wait_ms = 1;
    cfg.tuning_path = tuning.cloned();
    Server::start(Coordinator::start(cfg)?, "127.0.0.1", 0)
}

/// `--tuning <path>` / `--tuning=<path>` from the example's arguments.
/// A dangling `--tuning` is an error, not a silent untuned run.
fn tuning_arg() -> Result<Option<PathBuf>> {
    flash_sdkde::util::cli::scan_raw_option("tuning", std::env::args().skip(1))
        .map(|o| o.map(PathBuf::from))
        .map_err(anyhow::Error::msg)
}

fn main() -> Result<()> {
    let tuning = tuning_arg()?;
    if let Some(path) = &tuning {
        println!("booting workers with tuning table {}", path.display());
    }
    // Three loopback workers, each a full native-backend coordinator.
    let mut workers: Vec<Server> = Vec::new();
    for _ in 0..3 {
        workers.push(worker(tuning.as_ref())?);
    }
    let mut router_cfg = RouterConfig::default();
    router_cfg.nodes =
        workers.iter().map(|w| w.local_addr().to_string()).collect();
    router_cfg.connect_timeout_ms = 500;
    router_cfg.retries = 2;
    let router_server =
        RouterServer::start(Router::new(router_cfg)?, "127.0.0.1", 0)?;
    let table = router_server.router().table();
    println!(
        "cluster up: router {} over {:?} (epoch {})",
        router_server.local_addr(),
        table.nodes(),
        table.epoch()
    );

    // Fit six models through the router; placement is deterministic.
    let d = 2;
    let mix = by_dim(d);
    let mut rng = Pcg64::seeded(1);
    let mut client = Client::connect(router_server.local_addr())?;
    let names: Vec<String> = (0..6).map(|i| format!("tenant-{i}")).collect();
    for name in &names {
        let info =
            client.fit(name, mix.sample(256, &mut rng), &FitSpec::new(EstimatorKind::SdKde, d))?;
        println!(
            "  fit {name} (n={}, h={:.4}) -> {}",
            info.n,
            info.h,
            table.owner(name).expect("owner")
        );
    }

    // Queries follow their model to the owning node.
    let queries = mix.sample(4, &mut rng);
    for name in &names {
        let res = client.eval(name, d, queries.clone())?;
        println!("  eval {name}: p[0] = {:.6}", res.values[0]);
    }

    // One aggregated stats document for the whole fleet.
    println!("fleet stats: {}", json::to_string(&client.stats()?));

    // Unplug a worker: routed ops for its models fail typed (and fast).
    let victim = table.owner(&names[0]).expect("owner").to_string();
    let idx = workers
        .iter()
        .position(|w| w.local_addr().to_string() == victim)
        .expect("victim index");
    drop(workers.remove(idx));
    match client.eval(&names[0], d, queries.clone()) {
        Err(e) => println!("after killing {victim}: typed error: {e:#}"),
        Ok(_) => println!("unexpected: {victim} still answered"),
    }

    // Failover: drop the node from the table (epoch bumps), re-fit the
    // orphaned model through the router, and serving resumes.
    router_server.router().remove_node(&victim);
    let updated = router_server.router().table();
    println!(
        "table updated: {:?} (epoch {})",
        updated.nodes(),
        updated.epoch()
    );
    client.fit(
        &names[0],
        mix.sample(256, &mut rng),
        &FitSpec::new(EstimatorKind::SdKde, d),
    )?;
    let res = client.eval(&names[0], d, queries)?;
    println!(
        "re-routed {} to {}: p[0] = {:.6}",
        names[0],
        updated.owner(&names[0]).expect("owner"),
        res.values[0]
    );
    Ok(())
}
