//! End-to-end serving driver (the DESIGN.md §7 E2E experiment).
//!
//! Boots the full stack — coordinator, dispatcher, TCP server — fits an
//! SD-KDE model over the 16-D benchmark mixture through the typed
//! `FitSpec` wire path, then drives an open-loop Poisson workload from
//! concurrent TCP clients and reports throughput, latency percentiles,
//! batching behaviour and numerical correctness against the native
//! oracle.  Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_queries
//! ```

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use flash_sdkde::config::Config;
use flash_sdkde::coordinator::server::{Client, Server};
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::data::workload::{generate, TraceSpec};
use flash_sdkde::estimator::{native, EstimatorKind};
use flash_sdkde::util::rng::Pcg64;
use flash_sdkde::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into();
    cfg.batch_wait_ms = 2;
    cfg.port = 0; // ephemeral
    // No artifacts? Serve the pure-Rust native flash backend instead.
    let cfg = cfg.auto_backend();

    // --- boot ---------------------------------------------------------
    let coordinator = Coordinator::start(cfg.clone())?;
    let mut server = Server::start(coordinator, &cfg.host, 0)?;
    let addr = server.local_addr();
    println!("server on {addr}");

    // --- fit over TCP ---------------------------------------------------
    let d = 16;
    let n_train = 2000;
    let mix = by_dim(d);
    let mut rng = Pcg64::seeded(2026);
    let train = mix.sample(n_train, &mut rng);

    let mut admin = Client::connect(addr)?;
    println!("negotiated protocol v{}", admin.protocol_version());
    let t0 = Instant::now();
    let info = admin.fit(
        "serving-demo",
        train.clone(),
        &FitSpec::new(EstimatorKind::SdKde, d),
    )?;
    println!(
        "fit: n={} bucket={} h={:.4} h_score={:.4} ({:.0}ms over TCP, {:.0}ms total)",
        info.n,
        info.bucket_n,
        info.h,
        info.h_score,
        info.fit_ms,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // --- workload -------------------------------------------------------
    let clients = 4;
    let spec = TraceSpec {
        requests: 200,
        min_k: 1,
        max_k: 24,
        rate: Some(400.0), // aggregate target: clients share the trace
    };
    let trace = Arc::new(generate(&mix, &spec, &mut rng));
    println!(
        "driving {} requests ({} clients, Poisson {} req/s, k in [{}, {}])",
        spec.requests, clients, spec.rate.unwrap(), spec.min_k, spec.max_k
    );

    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let batch_sizes: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let errors: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));

    // Precompute the debiased training set once so the per-request oracle
    // check is a cheap O(n) KDE sweep, not an O(n^2) score pass.  The
    // resolved score bandwidth comes straight off the FitOk reply.
    let w_all = vec![1.0f32; n_train];
    let x_sd = Arc::new(native::debias(&train, &w_all, d, info.h, info.h_score));

    // Each client handles trace indices i ≡ c (mod clients), honouring
    // the shared arrival clock (open loop).
    let wall_start = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let trace = Arc::clone(&trace);
            let latencies = Arc::clone(&latencies);
            let batch_sizes = Arc::clone(&batch_sizes);
            let errors = Arc::clone(&errors);
            let x_sd = Arc::clone(&x_sd);
            let h = info.h;
            std::thread::spawn(move || -> anyhow::Result<()> {
                let mut client = Client::connect(addr)?;
                let w = vec![1.0f32; x_sd.len() / 16];
                for req in trace.iter().skip(c).step_by(clients) {
                    // Open-loop pacing against the shared clock.
                    let target = Duration::from_secs_f64(req.arrival_s);
                    if let Some(sleep) = target.checked_sub(wall_start.elapsed()) {
                        std::thread::sleep(sleep);
                    }
                    let sent = Instant::now();
                    let res = client.eval("serving-demo", 16, req.points.clone())?;
                    latencies
                        .lock()
                        .expect("mutex")
                        .push(sent.elapsed().as_secs_f64() * 1e3);
                    batch_sizes.lock().expect("mutex").push(res.batch_size as f64);
                    // Numerics spot-check on the first point of each reply:
                    // KDE over the precomputed debiased set == SD-KDE.
                    let oracle =
                        native::kde(&x_sd, &w, &req.points[..16], 16, h)[0];
                    let rel = ((res.values[0] as f64 - oracle) / oracle).abs();
                    errors.lock().expect("mutex").push(rel);
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread")?;
    }
    let wall = wall_start.elapsed().as_secs_f64();

    // --- report ----------------------------------------------------------
    let lat = Summary::of(&latencies.lock().expect("mutex"));
    let bs = Summary::of(&batch_sizes.lock().expect("mutex"));
    let err = Summary::of(&errors.lock().expect("mutex"));
    let served = lat.count;
    println!("\n=== serving report ===");
    println!("requests served : {served} in {wall:.2}s  ({:.1} req/s)", served as f64 / wall);
    println!(
        "latency ms      : p50={:.2} p95={:.2} p99={:.2} max={:.2}",
        lat.median, lat.p95, lat.p99, lat.max
    );
    println!("mean batch size : {:.2} (max {:.0})", bs.mean, bs.max);
    println!("max rel error   : {:.2e} vs native oracle", err.max);
    let stats = admin.stats()?;
    println!("server stats    : {}", flash_sdkde::util::json::to_string(&stats));

    anyhow::ensure!(err.max < 1e-3, "serving numerics diverged");
    anyhow::ensure!(served == spec.requests, "dropped requests");
    server.shutdown();
    println!("serve_queries OK");
    Ok(())
}
