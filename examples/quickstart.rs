//! Quickstart: fit an SD-KDE model in-process and query densities.
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API surface: config -> coordinator ->
//! `FitSpec` -> `ModelHandle` -> eval, then cross-checks the served
//! densities against the native Rust oracle.  The handle carries every
//! resolved fit parameter — including the score bandwidth — so nothing
//! has to be re-derived by hand.

use flash_sdkde::config::Config;
use flash_sdkde::coordinator::{Coordinator, FitSpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::estimator::{native, EstimatorKind};
use flash_sdkde::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into();
    // No artifacts? Serve the pure-Rust native flash backend instead.
    let cfg = cfg.auto_backend();

    println!(
        "booting coordinator (artifacts: {}, backend: {})...",
        cfg.artifacts_dir.display(),
        cfg.backend
    );
    let coordinator = Coordinator::start(cfg)?;

    // 1. Draw training data from the 16-D benchmark mixture.
    let d = 16;
    let mix = by_dim(d);
    let mut rng = Pcg64::seeded(7);
    let n = 1000;
    let train = mix.sample(n, &mut rng);

    // 2. Fit: SD-KDE debiases the samples with the empirical score
    //    (the paper's expensive pass, served by the flash fit artifact).
    //    No overrides: bandwidths resolve to the SD-rate rule and
    //    h / sqrt(2), the variant to the config default (flash).
    let handle =
        coordinator.fit("quickstart", train.clone(), &FitSpec::new(EstimatorKind::SdKde, d))?;
    println!(
        "fitted model {:?}: n={} bucket={} h={:.4} h_score={:.4} in {:.1}ms",
        handle.name(),
        handle.n(),
        handle.bucket_n(),
        handle.h(),
        handle.h_score(),
        handle.info().fit_ms
    );

    // 3. Evaluate densities at fresh query points.
    let k = 16;
    let queries = mix.sample(k, &mut rng);
    let result = coordinator.eval(&handle, queries.clone())?;
    println!("\n  density      true pdf");
    let truth = mix.pdf(&queries);
    for (est, tru) in result.values.iter().zip(&truth) {
        println!("  {est:.6e}  {tru:.6e}");
    }
    println!(
        "\nserved in {:.2}ms exec (+{:.2}ms queue), batch size {}",
        result.exec_ms, result.queue_ms, result.batch_size
    );

    // 4. Cross-check against the native oracle (same formulas, f64),
    //    using the resolved score bandwidth straight off the handle.
    let w = vec![1.0f32; n];
    let oracle = native::sdkde(&train, &w, &queries, d, handle.h(), handle.h_score());
    let max_rel = result
        .values
        .iter()
        .zip(&oracle)
        .map(|(&a, &b)| ((a as f64 - b) / b).abs())
        .fold(0.0f64, f64::max);
    println!("max relative deviation vs native oracle: {max_rel:.2e}");
    anyhow::ensure!(max_rel < 1e-3, "served densities diverge from oracle");
    println!("quickstart OK");
    Ok(())
}
