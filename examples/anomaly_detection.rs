//! Domain example: density-based anomaly detection.
//!
//! The intro-motivating use case for fast high-dimensional density
//! estimation: score incoming 16-D feature vectors by their estimated
//! density under normal traffic and flag low-density points as anomalies.
//! SD-KDE's bias correction matters here — vanilla KDE oversmooths the
//! density precisely in the tails where the detection threshold lives.
//!
//! Scores are served in log space (`QuerySpec::log_density`, the natural
//! scale for thresholding 16-D densities that underflow f32 fast), one of
//! the three output modes of the unified query path.
//!
//! ```bash
//! make artifacts && cargo run --release --example anomaly_detection
//! ```

use flash_sdkde::config::Config;
use flash_sdkde::coordinator::{Coordinator, FitSpec, QuerySpec};
use flash_sdkde::data::mixture::by_dim;
use flash_sdkde::estimator::EstimatorKind;
use flash_sdkde::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = std::env::var("FLASH_SDKDE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into();
    // No artifacts? Serve the pure-Rust native flash backend instead.
    let cfg = cfg.auto_backend();
    let coordinator = Coordinator::start(cfg)?;

    let d = 16;
    let mix = by_dim(d);
    let mut rng = Pcg64::seeded(99);

    // "Normal" traffic: the benchmark mixture.
    let n = 1500;
    let train = mix.sample(n, &mut rng);
    let baseline =
        coordinator.fit("normal-traffic", train, &FitSpec::new(EstimatorKind::SdKde, d))?;
    println!(
        "baseline model: n={} h={:.4} ({}ms fit)",
        baseline.n(),
        baseline.h(),
        baseline.info().fit_ms as u64
    );

    // Test stream: 48 normal points + 12 anomalies (far off-manifold).
    let normal = mix.sample(48, &mut rng);
    let mut anomalies = Vec::new();
    for _ in 0..12 {
        for _ in 0..d {
            // Uniform noise far outside the mixture's support envelope.
            anomalies.push(rng.uniform_range(-12.0, 12.0) as f32);
        }
    }
    let mut stream = normal.clone();
    stream.extend_from_slice(&anomalies);
    let labels: Vec<bool> = std::iter::repeat(false)
        .take(48)
        .chain(std::iter::repeat(true).take(12))
        .collect();

    let result = coordinator.query(&baseline, QuerySpec::log_density(stream))?;

    // Threshold at the 10th percentile of the *normal* calibration scores.
    let mut calib: Vec<f64> = result.values[..48]
        .iter()
        .map(|&v| v as f64)
        .collect();
    calib.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let threshold = calib[4]; // ~10th percentile of 48
    println!("threshold (p10 of normal log-scores): {threshold:.2}\n");

    println!("  idx  log p̂      verdict    truth");
    let mut tp = 0;
    let mut fp = 0;
    let mut fn_ = 0;
    for (i, (&score, &is_anomaly)) in
        result.values.iter().zip(&labels).enumerate()
    {
        let flagged = (score as f64) < threshold;
        match (flagged, is_anomaly) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fn_ += 1,
            _ => {}
        }
        if flagged || is_anomaly {
            println!(
                "  {i:>3}  {score:>8.2}  {}  {}",
                if flagged { "ANOMALY " } else { "normal  " },
                if is_anomaly { "anomaly" } else { "normal" }
            );
        }
    }
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fn_).max(1) as f64;
    println!("\nprecision={precision:.2} recall={recall:.2} (tp={tp} fp={fp} fn={fn_})");
    anyhow::ensure!(recall >= 0.9, "detector missed too many anomalies");
    println!("anomaly_detection OK");
    Ok(())
}
